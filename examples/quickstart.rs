//! Quickstart: load the AOT artifacts, run one real inference through the
//! PJRT runtime, verify numerics against the python-computed golden, then
//! push a small burst through the OoO VLIW JIT.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};

use vliw_jit::compiler::ir::{DispatchRequest, StreamId};
use vliw_jit::compiler::jit::{JitCompiler, JitConfig};
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::runtime::PjrtExecutor;

fn main() -> Result<()> {
    // 1. load artifacts (compiled once by `make artifacts`; python is NOT
    //    on this path — we only read HLO text + weight blobs)
    let mut ex = PjrtExecutor::from_default_artifacts()
        .context("run `make artifacts` first")?;
    println!("loaded manifest with {} models", ex.manifest().models.len());

    // 2. single real inference: mlp_small, batch 1
    let x = vec![0.1f32; 256];
    let out = ex
        .execute_model("mlp_small", &[x])
        .context("execute mlp_small")?;
    println!(
        "mlp_small b1: {} outputs in {:.2} ms (first: {:.4})",
        out.outputs[0].len(),
        out.duration_us / 1e3,
        out.outputs[0][0]
    );

    // 3. end-to-end numeric self-check vs the python reference
    let err = ex
        .golden_check_model("mlp_small", 4)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("golden check (mlp_small b4): max rel err {err:.2e} — numerics OK");

    // 4. declarative dispatch through the OoO VLIW JIT: four independent
    //    streams issue class-A GEMMs; the JIT coalesces them into ONE
    //    superkernel launch of the real Pallas batched artifact
    let mut jit = JitCompiler::new(JitConfig::default(), ex);
    let ops: Vec<(f64, DispatchRequest)> = (0..4)
        .map(|s| {
            (
                0.0,
                DispatchRequest::new(StreamId(s), KernelDesc::gemm(32, 256, 256), 1e6)
                    .with_tag(s as u64),
            )
        })
        .collect();
    let done = jit.run_trace(ops);
    println!(
        "JIT: {} ops -> {} superkernel launch(es), mean pack {:.1}, pack eff {:.2}",
        done.len(),
        jit.stats.launches,
        jit.stats.mean_pack(),
        jit.stats.pack_efficiency()
    );
    for c in &done {
        println!(
            "  stream {} op {:?}: latency {:.2} ms (pack of {})",
            c.op.stream.0,
            c.op.id,
            c.latency_us() / 1e3,
            c.pack_size
        );
    }
    assert_eq!(jit.stats.launches, 1, "4 compatible streams must coalesce");
    println!("quickstart OK");
    Ok(())
}
