//! OoO reordering + staggering demo on REAL artifacts: shows the scheduler
//! (a) reordering across streams so a tight-SLO op jumps a relaxed one,
//! and (b) staggering a lone kernel until shape-compatible work arrives,
//! executing everything as coalesced Pallas superkernels via PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example ooo_reordering
//! ```

use anyhow::{Context, Result};

use vliw_jit::compiler::ir::{DispatchRequest, StreamId};
use vliw_jit::compiler::jit::{JitCompiler, JitConfig};
use vliw_jit::compiler::{Coalescer, Policy};
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::runtime::PjrtExecutor;

fn main() -> Result<()> {
    let mut ex = PjrtExecutor::from_default_artifacts().context("make artifacts")?;
    ex.warmup_supers().map_err(|e| anyhow::anyhow!("{e}"))?;

    // Scenario 1: REORDERING. Stream 0 submits a big class-C GEMM with a
    // relaxed SLO at t=0; stream 1 submits a tiny class-A GEMM with a tight
    // SLO at t=0. EDF must issue the class-A op first even though it
    // arrived second in program order.
    println!("-- scenario 1: SLO-aware reordering --");
    let mut jit = JitCompiler::new(JitConfig::default(), ex);
    let done = jit.run_trace(vec![
        (
            0.0,
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(64, 1024, 1024), 5e6)
                .with_tag(100),
        ),
        (
            0.0,
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(32, 256, 256), 30_000.0)
                .with_tag(200),
        ),
    ]);
    for c in &done {
        println!(
            "  tag {} (stream {}): issued @{:.2} ms, done @{:.2} ms, {}",
            c.op.tag,
            c.op.stream.0,
            c.issue_us / 1e3,
            c.done_us / 1e3,
            if c.met_deadline { "SLO MET" } else { "SLO MISSED" }
        );
    }
    let tight = done.iter().find(|c| c.op.tag == 200).unwrap();
    let relaxed = done.iter().find(|c| c.op.tag == 100).unwrap();
    assert!(
        tight.issue_us <= relaxed.issue_us,
        "tight-SLO op must issue first (OoO reorder)"
    );
    assert!(tight.met_deadline);

    // Scenario 2: STAGGERING. One class-B op arrives with slack; three more
    // compatible ops trickle in over the next 1.5 ms. The JIT holds the
    // first op (purposeful delay, §5.2) and launches all four as ONE
    // superkernel on the real super_B_p4 artifact.
    println!("-- scenario 2: stagger-for-coalescing --");
    let ex2 = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    let mut jit2 = JitCompiler::new(JitConfig::default(), ex2);
    let ops: Vec<(f64, DispatchRequest)> = (0..4)
        .map(|i| {
            (
                i as f64 * 500.0, // 0, 0.5, 1.0, 1.5 ms
                DispatchRequest::new(StreamId(i), KernelDesc::gemm(32, 512, 512), 1e6)
                    .with_tag(i as u64),
            )
        })
        .collect();
    let done2 = jit2.run_trace(ops);
    println!(
        "  4 staggered arrivals -> {} launch(es), mean pack {:.1}",
        jit2.stats.launches,
        jit2.stats.mean_pack()
    );
    for c in &done2 {
        println!(
            "  tag {}: arrived @{:.2} ms, issued @{:.2} ms (waited {:.2} ms), pack of {}",
            c.op.tag,
            c.op.arrival_us / 1e3,
            c.issue_us / 1e3,
            (c.issue_us - c.op.arrival_us) / 1e3,
            c.pack_size
        );
    }
    assert_eq!(jit2.stats.launches, 1, "staggering must merge all four");

    // Scenario 3: the SAME arrivals with a zero coalescing window
    // (early-binding): four separate launches, 4x the device work.
    println!("-- scenario 3: same workload, no staggering (early binding) --");
    let ex3 = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    let cfg = JitConfig {
        policy: Policy {
            coalesce_window_us: 0.0,
            target_pack: 1,
            ..Policy::default()
        },
        coalescer: Coalescer::new(1, 0.75), // early binding: one kernel/launch
        ..JitConfig::default()
    };
    let mut jit3 = JitCompiler::new(cfg, ex3);
    let ops3: Vec<(f64, DispatchRequest)> = (0..4)
        .map(|i| {
            (
                i as f64 * 500.0,
                DispatchRequest::new(StreamId(i), KernelDesc::gemm(32, 512, 512), 1e6),
            )
        })
        .collect();
    let _ = jit3.run_trace(ops3);
    println!(
        "  {} launches (vs 1 coalesced); per-launch JIT+dispatch overhead is \
         paid {}x instead of once",
        jit3.stats.launches, jit3.stats.launches
    );
    assert_eq!(jit3.stats.launches, 4);
    // NOTE: on the single-core CPU-PJRT backend the packed superkernel's
    // wall time is ~the sum of its members (no SM-level parallelism to
    // exploit), so the win here is launch-count, scheduling and SLO
    // control. The *throughput* gains of packing on a parallel device are
    // quantified by the V100 simulator (see `multi_tenant` and the fig6
    // bench: 7.7x over time-mux).
    println!(
        "  device busy: {:.2} ms coalesced vs {:.2} ms early-binding (CPU backend)",
        jit2.stats.busy_us / 1e3,
        jit3.stats.busy_us / 1e3
    );
    println!("ooo_reordering OK");
    Ok(())
}
