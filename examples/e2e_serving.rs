//! END-TO-END serving driver (the repo's headline validation run).
//!
//! Loads the real compiled models, serves a multi-tenant Poisson workload
//! through the full stack — tenants → the shared OoO JIT core (EDF +
//! coalescing window + per-model groups) → padded batch variants → PJRT
//! CPU execution of the AOT Pallas models — and reports per-tenant latency
//! (p50/p99), throughput, SLO attainment, batch occupancy and JIT pack
//! stats, against the batch-1 FIFO baseline. A final section drives the
//! *concurrent* real-time path: 3 models execute on 3 pool workers (one
//! PJRT backend each) in parallel.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use anyhow::{Context, Result};

use vliw_jit::placement::{DeviceTopology, RebalanceConfig};
use vliw_jit::runtime::PjrtExecutor;
use vliw_jit::serve::{BatchPolicy, Server, SimBackend};
use vliw_jit::workload::trace::{ArrivalKind, Request, TenantSpec, Trace};

fn tenants() -> Vec<TenantSpec> {
    // 9 tenants, 3 models, mixed SLOs (tight/medium/relaxed), one bursty
    // tenant per model — the paper's interactive-plus-batch mix (§2)
    let mut ts = Vec::new();
    for (i, (model, rate)) in [
        ("mlp_small", 150.0),
        ("gemmnet6", 50.0),
        ("mlp_large", 30.0),
    ]
    .iter()
    .enumerate()
    {
        for j in 0..3u32 {
            let id = (i as u32) * 3 + j;
            let (slo, kind) = match j {
                0 => (30_000u64, ArrivalKind::Poisson), // 30 ms interactive
                1 => (100_000, ArrivalKind::Poisson),   // 100 ms
                _ => (500_000, ArrivalKind::Bursty),    // 500 ms batchy
            };
            ts.push(TenantSpec::new(id, model, slo, *rate, kind));
        }
    }
    ts
}

fn main() -> Result<()> {
    let per_tenant = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120usize);
    let seed = 42;

    let trace = Trace::generate(&tenants(), per_tenant, seed);
    println!(
        "workload: {} requests, 9 tenants x 3 models, offered {:.0} req/s, span {:.2} s",
        trace.requests.len(),
        trace.offered_load(),
        trace.span_us() / 1e6
    );

    // --- the OoO coalescing server ---
    let mut ex = PjrtExecutor::from_default_artifacts().context("make artifacts")?;
    let mut compile_ms = 0.0;
    for m in ["mlp_small", "mlp_large", "gemmnet6"] {
        compile_ms += ex.warmup_model(m).map_err(|e| anyhow::anyhow!("{e}"))? / 1e3;
    }
    println!("warmup: compiled all variants in {compile_ms:.0} ms (off the request path)\n");

    let mut server = Server::new(ex, BatchPolicy::coalescing());
    let coal = server.replay(&trace);
    println!("{}", coal.render());

    // --- batch-1 FIFO baseline (early-binding dispatch) ---
    let ex2 = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    let mut base = Server::new(ex2, BatchPolicy::NoBatching);
    let fifo = base.replay(&trace);
    println!("{}", fifo.render());

    // --- headline comparison ---
    let speedup = fifo
        .metrics
        .busy_us
        .max(1.0)
        .min(f64::INFINITY)
        / coal.metrics.busy_us.max(1.0);
    println!("== e2e summary ==");
    println!(
        "device-time reduction (fifo busy / coalesced busy): {speedup:.2}x  \
         | occupancy {:.1} vs {:.1} rows/batch",
        coal.metrics.mean_occupancy(),
        fifo.metrics.mean_occupancy()
    );
    println!(
        "throughput: coalesced {:.0} req/s vs fifo {:.0} req/s",
        coal.metrics.throughput(),
        fifo.metrics.throughput()
    );
    println!(
        "SLO attainment: coalesced {:.3} vs fifo {:.3}",
        coal.metrics.overall_attainment(),
        fifo.metrics.overall_attainment()
    );
    if coal.metrics.overall_attainment() < fifo.metrics.overall_attainment() {
        println!("WARNING: coalescing lost attainment — check policy knobs");
    }
    println!(
        "jit core: launches={} mean_pack={:.2} pack_eff={:.2} evictions={}",
        coal.metrics.jit.launches,
        coal.metrics.jit.mean_pack(),
        coal.metrics.jit.pack_efficiency(),
        coal.metrics.jit.evictions
    );

    // --- single-tenant burst: stream-prefix coalescing ---
    // one hot tenant fires 16 requests 100µs apart at one model; serving
    // requests are independent, so the burst rides a few superkernels
    // instead of 16 singleton launches (the pre-independence behavior)
    println!("\n== single-tenant burst (stream-prefix coalescing) ==");
    let burst: Vec<Request> = (0..16)
        .map(|i| Request {
            id: i,
            tenant: 0,
            model: "mlp_small".to_string(),
            arrival_us: i as f64 * 100.0,
            deadline_us: i as f64 * 100.0 + 100_000.0,
        })
        .collect();
    let burst_trace = Trace {
        requests: burst,
        tenants: vec![TenantSpec::new(
            0,
            "mlp_small",
            100_000,
            10_000.0,
            ArrivalKind::Poisson,
        )],
    };
    let mut exb = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    exb.warmup_model("mlp_small").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut bs = Server::new(exb, BatchPolicy::coalescing());
    let br = bs.replay(&burst_trace);
    println!(
        "burst: launches={} mean_pack={:.2} same_stream_rows={} attain={:.3}",
        br.metrics.jit.launches,
        br.metrics.jit.mean_pack(),
        br.metrics.same_stream_rows,
        br.metrics.overall_attainment()
    );
    assert!(
        br.metrics.jit.mean_pack() > 1.0,
        "a single tenant's burst must coalesce"
    );

    // --- concurrent real-time path: 3 models on 3 pool workers ---
    // Each worker owns its own PJRT executor (built + warmed on its own
    // thread), so superkernels for different models execute in parallel;
    // the shared JIT core keeps making every hold/launch decision.
    println!("\n== real-time concurrent launch stage (3 workers) ==");
    let rt_trace = Trace::generate(&tenants(), per_tenant.min(40), seed);
    let ex3 = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    let mut rt = Server::new(ex3, BatchPolicy::coalescing());
    let report = rt.run_realtime_pooled(&rt_trace, 4.0, 3, |i| {
        let mut ex = PjrtExecutor::from_default_artifacts().expect("worker artifacts");
        for m in ["mlp_small", "mlp_large", "gemmnet6"] {
            let _ = ex.warmup_model(m);
        }
        eprintln!("worker {i} ready");
        ex
    });
    println!("{}", report.render());
    assert!(
        report.metrics.jit.launches > 0,
        "concurrent path must serve through the JIT core"
    );

    // --- one engine, many modes: replay == replay_placed on one v100 ---
    // Every drive mode is the same Clock × LaunchStage loop since the
    // unified-engine refactor: the single-device virtual replay is
    // literally the placed replay on a one-v100 topology (minus the
    // per-device metrics), so their schedules agree bit for bit.
    println!("\n== unified engine (replay == replay_placed on one v100) ==");
    let eq_tenants = vec![
        TenantSpec::new(0, "a", 50_000, 300.0, ArrivalKind::Poisson),
        TenantSpec::new(1, "b", 50_000, 300.0, ArrivalKind::Bursty),
    ];
    let eq_trace = Trace::generate(&eq_tenants, 60, 11);
    let mut eq_plain = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let eq_r1 = eq_plain.replay(&eq_trace);
    let one_v100 = DeviceTopology::from_names(&["v100".to_string()])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut eq_placed = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let (eq_r2, _) = eq_placed.replay_placed(&eq_trace, &one_v100, None);
    println!(
        "replay: {} done, span {:.1} ms | replay_placed(1x v100): {} done, span {:.1} ms",
        eq_r1.metrics.total_completed(),
        eq_r1.metrics.span_us / 1e3,
        eq_r2.metrics.total_completed(),
        eq_r2.metrics.span_us / 1e3,
    );
    assert_eq!(
        eq_r1.metrics.span_us.to_bits(),
        eq_r2.metrics.span_us.to_bits(),
        "one engine: the two modes must produce the same schedule"
    );
    assert_eq!(eq_r1.metrics.total_completed(), eq_r2.metrics.total_completed());

    // --- device placement: a hot model replicates onto a second device ---
    // A heterogeneous v100+t4 fleet serves a skewed two-model workload on
    // the deterministic simulator backend: `hot` overloads the v100 it was
    // initially placed on, the rebalancer replicates it onto the t4
    // mid-run, and aggregate throughput beats the same trace pinned to the
    // initial static placement at no worse attainment.
    println!("\n== device placement (v100 + t4, hot-group replication) ==");
    let placed_tenants = vec![
        TenantSpec::new(0, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
        TenantSpec::new(1, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
        TenantSpec::new(2, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
        TenantSpec::new(3, "cold", 30_000, 300.0, ArrivalKind::Poisson),
    ];
    let placed_trace = Trace::generate(&placed_tenants, 400, 71);
    let topo = DeviceTopology::from_names(&["v100".to_string(), "t4".to_string()])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let heavy = SimBackend {
        fixed_us: 200.0,
        per_row_us: 200.0,
        max_b: 8,
        d_in: 4,
    };
    let mut placed = Server::new(heavy.clone(), BatchPolicy::coalescing());
    let (dynamic, table) = placed.replay_placed(
        &placed_trace,
        &topo,
        Some(RebalanceConfig {
            window_us: 25_000.0,
            ..RebalanceConfig::default()
        }),
    );
    let mut pinned = Server::new(heavy, BatchPolicy::coalescing());
    let (static_run, _) = pinned.replay_placed(&placed_trace, &topo, None);
    println!("{}", dynamic.render());
    println!(
        "hot-group replicas: {:?}  (replications={}, migrations={})",
        table.replicas_of(1),
        dynamic.metrics.replications,
        dynamic.metrics.migrations
    );
    println!(
        "throughput: rebalanced {:.0} req/s vs pinned {:.0} req/s  | attainment {:.3} vs {:.3}",
        dynamic.metrics.throughput(),
        static_run.metrics.throughput(),
        dynamic.metrics.overall_attainment(),
        static_run.metrics.overall_attainment()
    );
    assert!(
        dynamic.metrics.replications >= 1,
        "the hot model must replicate onto the second device"
    );
    assert!(
        dynamic.metrics.throughput() > static_run.metrics.throughput(),
        "replication must buy aggregate throughput"
    );

    // --- async admission frontend: decisions decoupled from the loop ---
    // The same simulated workload through both wall-clock gates: the
    // frontend stage (default) prices requests against the published
    // AdmissionView snapshot on its own thread, so its arrival→decision
    // p99 stays flat no matter what the scheduler iteration is doing;
    // attainment must not regress vs the synchronous gate.
    println!("\n== async admission frontend (vs synchronous gate) ==");
    let fe_trace = Trace::generate(&tenants(), per_tenant.min(60), seed);
    let mut fe_srv = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let fe_run = fe_srv.run_realtime(&fe_trace, 4.0);
    let mut sync_srv = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    sync_srv.frontend = false;
    let sync_run = sync_srv.run_realtime(&fe_trace, 4.0);
    println!(
        "admission p99: frontend {:.2} ms vs sync {:.2} ms  | attainment {:.3} vs {:.3}  | stale decisions {}",
        fe_run.metrics.admission_latency.quantile_us(0.99) / 1e3,
        sync_run.metrics.admission_latency.quantile_us(0.99) / 1e3,
        fe_run.metrics.overall_attainment(),
        sync_run.metrics.overall_attainment(),
        fe_run.metrics.stale_decisions,
    );
    assert_eq!(
        fe_run.metrics.admission_decisions,
        fe_trace.requests.len() as u64,
        "the frontend must decide every request"
    );

    println!("e2e_serving OK");
    Ok(())
}
