//! Multi-tenant GPU-scale scenario (Fig. 4/5 workload) on the V100
//! simulator: 10 tenants serve ResNet-50-class models under the three
//! multiplexing disciplines; reports per-tenant mean latency, variability
//! and SLO misses — the behaviour §4 calls "ineffective GPU multiplexing" —
//! and then the JIT's coalesced schedule.
//!
//! ```bash
//! cargo run --release --example multi_tenant [replicas]
//! ```

use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::kernel::LaunchConfig;
use vliw_jit::gpu::multiplex::{
    batched_oracle, coalesced, replicate_jobs, spatial_mux, time_mux,
};
use vliw_jit::gpu::timeline::SharingModel;
use vliw_jit::model::zoo::by_name;
use vliw_jit::util::stats::Streaming;

fn main() {
    let replicas: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let slo_ms = 75.0;
    let cm = CostModel::v100();
    let model = by_name("resnet50").expect("zoo model");
    let layers = model.gemms(1);
    println!(
        "workload: {replicas} tenants x resnet50 ({} kernels/query, {:.1} GFLOP), SLO {slo_ms} ms on V100\n",
        layers.len(),
        model.flops() / 1e9
    );

    // --- time multiplexing (§4.1) ---
    let tm = time_mux(&cm, &replicate_jobs(&layers, replicas));
    report("time-mux", &tm.jobs, slo_ms, tm.utilization);

    // --- spatial multiplexing (§4.2) ---
    let sp = spatial_mux(
        &cm,
        SharingModel::default(),
        &replicate_jobs(&layers, replicas),
    );
    report("spatial-mux", &sp.jobs, slo_ms, sp.utilization);

    // --- the JIT: per-layer VLIW coalescing across tenants (§5) ---
    let coal_us = coalesced(&cm, &layers, replicas, &LaunchConfig::greedy(), 2.0);
    println!(
        "{:<12} every tenant: {:.2} ms  (single coalesced schedule)  SLO {}",
        "vliw-jit",
        coal_us / 1e3,
        if coal_us / 1e3 <= slo_ms { "MET" } else { "MISSED" }
    );

    // --- batch oracle lower bound ---
    let oracle_us = batched_oracle(&cm, &layers, replicas);
    println!(
        "{:<12} every tenant: {:.2} ms  (whole-batch lower bound)\n",
        "batch-oracle",
        oracle_us / 1e3
    );

    let tm_mean = tm.jobs.iter().map(|j| j.latency_us).sum::<f64>() / replicas as f64;
    println!(
        "== summary: JIT is {:.1}x faster than time-mux, {:.1}x vs spatial, within {:.1}x of oracle ==",
        tm_mean / coal_us,
        (sp.jobs.iter().map(|j| j.latency_us).sum::<f64>() / replicas as f64) / coal_us,
        coal_us / oracle_us
    );
}

fn report(name: &str, jobs: &[vliw_jit::gpu::multiplex::JobCompletion], slo_ms: f64, util: f64) {
    let mut s = Streaming::new();
    for j in jobs {
        s.push(j.latency_us / 1e3);
    }
    let misses = jobs.iter().filter(|j| j.latency_us / 1e3 > slo_ms).count();
    let stragglers: u32 = jobs.iter().map(|j| j.stragglers).sum();
    println!(
        "{name:<12} mean {:.2} ms  min {:.2}  max {:.2}  cov {:.2}  SLO misses {}/{}  stragglers {}  util {:.2}",
        s.mean(),
        s.min(),
        s.max(),
        s.cov(),
        misses,
        jobs.len(),
        stragglers,
        util
    );
}
