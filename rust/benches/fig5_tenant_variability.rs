//! Figure 5: spatial multiplexing gives unpredictable per-tenant latency;
//! adding replicas to a 10-tenant GPU causes scattered SLO misses, worse at
//! odd tenant counts.
//!
//! Paper claims reproduced (shape): per-tenant latency spread (CoV and
//! max/min) grows with tenant count; odd counts are more variable; a few
//! tenants straggle past the SLO while others are fine.

use vliw_jit::bench::{f, ms, Table};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::multiplex::{replicate_jobs, spatial_mux};
use vliw_jit::gpu::timeline::SharingModel;
use vliw_jit::model::zoo::by_name;
use vliw_jit::util::stats::Streaming;

fn main() {
    let cm = CostModel::v100();
    let layers = by_name("resnet50").expect("zoo").gemms(1);
    // the Fig. 5 phenomenon is *scattered* misses: a straggling tenant
    // blowing past what its peers achieve. We count a miss when a tenant
    // exceeds 1.3x the median latency of its own run (an SLO set to what
    // the operator would provision from typical behaviour).
    let slo_factor = 1.3;
    let seeds = [1u64, 2, 3, 4, 5];

    let mut t = Table::new(
        "Figure 5 — per-tenant latency variability vs tenant count (spatial mux, V100)",
        &["tenants", "mean_ms", "min_ms", "max_ms", "cov", "scattered_miss", "stragglers"],
    );
    let mut cov_by_n = Vec::new();
    // Steady-state measurement: the paper's replicas serve continuously,
    // so no tenant ever gets the device to itself. Two long-running
    // background streams (excluded from the statistics) keep the device
    // contended for the whole window, and each measured tenant serves one
    // query under that steady load.
    let background: Vec<_> = (0..10).flat_map(|_| layers.clone()).collect();
    for n in [2u32, 4, 6, 8, 10, 11, 12, 13, 14, 15] {
        let mut all = Streaming::new();
        let mut misses = 0usize;
        let mut total = 0usize;
        let mut stragglers = 0u32;
        for &seed in &seeds {
            let mut model = SharingModel::default();
            model.seed = seed;
            let mut jobs = replicate_jobs(&layers, n);
            for b in 0..2u32 {
                jobs.push(vliw_jit::gpu::multiplex::InferenceJob {
                    stream: n + b,
                    layers: background.clone(),
                    arrival_us: 0.0,
                });
            }
            let res = spatial_mux(&cm, model, &jobs);
            let fg: Vec<_> = res.jobs.iter().filter(|j| j.stream < n).collect();
            let mut lat: Vec<f64> = fg.iter().map(|j| j.latency_us).collect();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = lat[lat.len() / 2];
            for j in &fg {
                all.push(j.latency_us / 1e3);
                total += 1;
                if j.latency_us > slo_factor * median {
                    misses += 1;
                }
                stragglers += j.stragglers;
            }
        }
        cov_by_n.push((n, all.cov()));
        t.row(vec![
            n.to_string(),
            f(all.mean(), 1),
            f(all.min(), 1),
            f(all.max(), 1),
            f(all.cov(), 3),
            format!("{misses}/{total}"),
            stragglers.to_string(),
        ]);
    }
    t.emit();

    let cov2 = cov_by_n.iter().find(|(n, _)| *n == 2).unwrap().1;
    let cov13 = cov_by_n.iter().find(|(n, _)| *n == 13).unwrap().1;
    let _ = ms(0.0);
    println!("paper: variability grows with tenancy; odd tenant counts suffer more;");
    println!("       a few stragglers cause scattered SLO misses (\"unpredictable SLO misses\")");
    println!(
        "measured: CoV(2 tenants) = {cov2:.3} vs CoV(13 tenants) = {cov13:.3} -> reproduced: {}",
        if cov13 > cov2 { "YES" } else { "PARTIAL" }
    );
}
