//! Table 1: auto-tuning the blocking configuration — greedy (single-tenant
//! optimal) vs collaborative (co-tenancy optimal) kernels.
//!
//! Paper numbers: greedy 2.2 TFLOPS isolated / 4.5 TFLOPS multiplexed;
//! collaborative 1.5 / 6.1 — i.e. ~20% isolated degradation buys ~1.25-1.36x
//! multiplexed throughput. Both configurations emerge from the same grid
//! search with different objectives; nothing is hard-coded.

use vliw_jit::bench::{f, Table};
use vliw_jit::compiler::autotune::{autotune, residency_of};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::gpu::timeline::SharingModel;

fn main() {
    let cm = CostModel::v100();
    // Table 1 workload: conv2_2-class SGEMM co-resident with `tenants`
    // copies of itself (the paper multiplexes replicas of the same model)
    let k = KernelDesc::gemm(56 * 56, 64 * 9, 64);

    for tenants in [4u32, 6, 9] {
        let res = autotune(&cm, &k, tenants, &SharingModel::default());
        let mut t = Table::new(
            &format!("Table 1 — autotuned kernels, {tenants} co-tenants (V100)"),
            &["config", "tiles_mnk", "residency", "isolated_TFLOPS", "multiplexed_TFLOPS"],
        );
        t.row(vec![
            "greedy".into(),
            format!(
                "{}x{}x{}",
                res.greedy.config.tm, res.greedy.config.tn, res.greedy.config.tk
            ),
            f(res.greedy.config.residency, 2),
            f(res.greedy.isolated_tflops, 2),
            f(res.greedy.multiplexed_tflops, 2),
        ]);
        t.row(vec![
            "collaborative".into(),
            format!(
                "{}x{}x{}",
                res.collaborative.config.tm,
                res.collaborative.config.tn,
                res.collaborative.config.tk
            ),
            f(res.collaborative.config.residency, 2),
            f(res.collaborative.isolated_tflops, 2),
            f(res.collaborative.multiplexed_tflops, 2),
        ]);
        t.emit();
        println!(
            "  multiplexed speedup {:.2}x (paper 1.25x)  |  isolated degradation {:.0}% (paper ~20%)\n",
            res.multiplexed_speedup(),
            res.isolated_degradation() * 100.0
        );
    }

    // the residency model backing the search (documentation output)
    println!("residency model: smem(double-buffered A/B slabs)/128KiB");
    for (tm, tn, tk) in [(128u32, 128u32, 32u32), (64, 64, 32), (32, 32, 16)] {
        println!(
            "  tiles {tm}x{tn}x{tk} -> residency {:.2}",
            residency_of(tm, tn, tk)
        );
    }
}
