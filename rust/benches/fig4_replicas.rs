//! Figure 4: mean latency for 1–15 ResNet-50 replicas on a V100 under time
//! multiplexing vs spatial multiplexing vs whole-batch inference.
//!
//! Paper claims reproduced (shape): time-mux latency grows linearly with
//! replica count and is dramatically slower than batched inference;
//! spatial mux sits between, degraded and less predictable.

use vliw_jit::bench::{f, ms, Table};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::multiplex::{batched_oracle, replicate_jobs, spatial_mux, time_mux};
use vliw_jit::gpu::timeline::SharingModel;
use vliw_jit::model::zoo::by_name;

fn main() {
    let cm = CostModel::v100();
    let layers = by_name("resnet50").expect("zoo").gemms(1);

    let mut t = Table::new(
        "Figure 4 — mean latency vs ResNet-50 replica count (V100)",
        &["replicas", "time_mux_ms", "spatial_ms", "batched_ms", "tm/batched", "sp/batched"],
    );
    let mut lin_check = Vec::new();
    for r in 1..=15u32 {
        let tm = time_mux(&cm, &replicate_jobs(&layers, r)).mean_latency_us();
        let sp = spatial_mux(&cm, SharingModel::default(), &replicate_jobs(&layers, r))
            .mean_latency_us();
        let bo = batched_oracle(&cm, &layers, r);
        lin_check.push(tm);
        t.row(vec![
            r.to_string(),
            ms(tm),
            ms(sp),
            ms(bo),
            f(tm / bo, 1),
            f(sp / bo, 1),
        ]);
    }
    t.emit();

    // linearity of time-mux: correlation of latency with replica index
    let r15 = lin_check[14] / lin_check[0];
    println!("paper: \"inference latency increased linearly\" under time-mux;");
    println!(
        "measured: 15-replica time-mux latency is {:.1}x the 1-replica latency (linear => ~8x mean growth across queue positions)",
        r15
    );
    let sp8 = {
        let sp = spatial_mux(&cm, SharingModel::default(), &replicate_jobs(&layers, 8))
            .mean_latency_us();
        let tm = time_mux(&cm, &replicate_jobs(&layers, 8)).mean_latency_us();
        tm / sp
    };
    println!(
        "spatial vs time-mux at 8 replicas: {sp8:.1}x faster but still above batched — reproduced: {}",
        if r15 > 5.0 && sp8 > 1.5 { "YES" } else { "PARTIAL" }
    );
}
