//! Figure 6: coalesced kernels approach ideal FP throughput.
//!
//! Paper numbers: coalescing the ResNet-18 conv2_2 SGEMM across streams
//! yields geomean 7.71x throughput over time-multiplexing and 3.23x over
//! Hyper-Q spatial multiplexing; coalescing LSTM/RNN matrix-vector work
//! yields 2.48x over time-slicing.
//!
//! We sweep stream counts, report sustained TFLOPS per discipline on the
//! V100 model, and geomean the ratios exactly as the paper does.

use vliw_jit::bench::{f, Table};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::gpu::multiplex::kernel_throughput;
use vliw_jit::gpu::timeline::SharingModel;
use vliw_jit::util::stats::geomean;

fn main() {
    let cm = CostModel::v100();
    // ResNet-18 conv2_2 after im2col: (56*56) x (64*9) x 64
    let conv = KernelDesc::gemm(56 * 56, 64 * 9, 64);

    let mut t = Table::new(
        "Figure 6 — conv2_2 SGEMM sustained TFLOPS by multiplexing discipline (V100)",
        &["streams", "time_mux", "spatial", "coalesced", "coal/time", "coal/spatial"],
    );
    let mut vs_time = Vec::new();
    let mut vs_spatial = Vec::new();
    for s in [2u32, 4, 6, 8, 9, 12, 16] {
        let r = kernel_throughput(&cm, &conv, s, SharingModel::default());
        vs_time.push(r.coalesced_tflops / r.time_mux_tflops);
        vs_spatial.push(r.coalesced_tflops / r.spatial_tflops);
        t.row(vec![
            s.to_string(),
            f(r.time_mux_tflops, 2),
            f(r.spatial_tflops, 2),
            f(r.coalesced_tflops, 2),
            f(r.coalesced_tflops / r.time_mux_tflops, 2),
            f(r.coalesced_tflops / r.spatial_tflops, 2),
        ]);
    }
    t.emit();

    let g_time = geomean(&vs_time);
    let g_spatial = geomean(&vs_spatial);
    println!("paper:    coalesced/time-mux geomean 7.71x   coalesced/spatial 3.23x");
    println!("measured: coalesced/time-mux geomean {g_time:.2}x   coalesced/spatial {g_spatial:.2}x");
    println!(
        "shape reproduced: {}",
        if (4.0..14.0).contains(&g_time) && (1.8..6.0).contains(&g_spatial) {
            "YES (who-wins and factor magnitudes hold)"
        } else {
            "PARTIAL — see EXPERIMENTS.md"
        }
    );

    // LSTM GEMV coalescing (paper cites 2.48x over time-slicing [26])
    let gemv = KernelDesc::gemm(1, 1536, 4096); // LSTM-1024 cell gate GEMM, m=1
    let mut t2 = Table::new(
        "Figure 6b — LSTM matrix-vector coalescing (V100)",
        &["streams", "time_mux_TFLOPS", "coalesced_TFLOPS", "speedup"],
    );
    let mut gemv_speedups = Vec::new();
    for s in [4u32, 8, 16, 32] {
        let r = kernel_throughput(&cm, &gemv, s, SharingModel::default());
        gemv_speedups.push(r.coalesced_tflops / r.time_mux_tflops);
        t2.row(vec![
            s.to_string(),
            f(r.time_mux_tflops, 3),
            f(r.coalesced_tflops, 3),
            f(r.coalesced_tflops / r.time_mux_tflops, 2),
        ]);
    }
    t2.emit();
    println!(
        "paper: RNN/LSTM coalescing 2.48x over time-slicing; measured geomean {:.2}x",
        geomean(&gemv_speedups)
    );
}
