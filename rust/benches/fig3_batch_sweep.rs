//! Figure 3: ResNet-50 on V100 — latency vs throughput across batch sizes,
//! exposing the utilization gap.
//!
//! Paper claims reproduced (shape): at interactive latencies (small batch)
//! throughput is <25% of the 15.7 TFLOPS peak; even large batches struggle
//! to reach 40%.

use vliw_jit::bench::{f, ms, Table};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::model::zoo::by_name;

fn main() {
    let cm = CostModel::v100();
    let model = by_name("resnet50").expect("zoo");
    let peak = cm.device.peak_flops;

    let mut t = Table::new(
        "Figure 3 — ResNet-50 V100 batch sweep (latency vs throughput vs utilization)",
        &["batch", "latency_ms", "img_per_s", "sustained_TFLOPS", "util_vs_peak"],
    );
    let mut util_b1 = 0.0;
    let mut util_max: f64 = 0.0;
    for &b in &[1u32, 2, 4, 8, 16, 32, 64] {
        let layers = model.gemms(b);
        let lat_us: f64 = layers
            .iter()
            .map(|k| cm.profile_default(k).duration_us + cm.device.layer_overhead_us)
            .sum();
        let flops = model.flops() * b as f64;
        let tput = b as f64 / (lat_us / 1e6);
        let sustained = flops / (lat_us / 1e6);
        let util = sustained / peak;
        if b == 1 {
            util_b1 = util;
        }
        util_max = util_max.max(util);
        t.row(vec![
            b.to_string(),
            ms(lat_us),
            f(tput, 0),
            f(sustained / 1e12, 2),
            f(util, 3),
        ]);
    }
    t.emit();

    println!("paper: batch-1 <25-30% of peak; larger batches <40% of 15.7 TFLOPS");
    println!(
        "measured: batch-1 util {:.1}%, best util {:.1}%  -> reproduced: {}",
        util_b1 * 100.0,
        util_max * 100.0,
        if util_b1 < 0.30 && util_max < 0.60 {
            "YES"
        } else {
            "PARTIAL"
        }
    );
}
