//! §Perf instrument: micro-benchmarks of the L3 hot path.
//!
//! Measures (a) the JIT decision path — window submit → EDF sort → pack →
//! decide — at several window sizes, (b) coalescer packing throughput,
//! (c) PJRT dispatch overhead on a real compiled superkernel, (d) manifest
//! parse time. Targets (DESIGN.md §Perf): packing decision < 10 µs/op at
//! window ≤ 256; dispatch overhead ≪ kernel execution.

use vliw_jit::bench::{f, time_it, Table};
use vliw_jit::compiler::coalescer::Coalescer;
use vliw_jit::compiler::ir::{DispatchRequest, StreamId, TensorOp};
use vliw_jit::compiler::scheduler::{Decision, Policy, Scheduler};
use vliw_jit::compiler::window::Window;
use vliw_jit::compiler::OpId;
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::runtime::{Manifest, PjrtExecutor};
use vliw_jit::util::rng::Rng;

fn mixed_kernel(rng: &mut Rng) -> KernelDesc {
    let shapes = [
        (32u32, 256u32, 256u32),
        (32, 512, 512),
        (64, 1024, 1024),
        (128, 512, 64),
        (1, 1536, 4096),
    ];
    let (m, k, n) = *rng.choose(&shapes);
    KernelDesc::gemm(m, k, n)
}

fn main() {
    let mut t = Table::new(
        "Perf — L3 hot path microbenchmarks",
        &["path", "param", "median_us", "per_op_us"],
    );

    // (a) full decision path at varying window occupancy
    let cm = CostModel::v100();
    for &n in &[16usize, 64, 256] {
        let mut rng = Rng::new(7);
        let mut sched = Scheduler::new(Policy::default(), Coalescer::default());
        let timing = time_it(3, 20, || {
            let mut w = Window::new(n + 1);
            for s in 0..n {
                w.submit(
                    DispatchRequest::new(
                        StreamId(s as u32),
                        mixed_kernel(&mut rng),
                        1e9,
                    ),
                    0.0,
                )
                .unwrap();
            }
            // drain via decide+issue until empty (full scheduling work)
            let mut now = 0.0;
            loop {
                match sched.decide(&mut w, now, 0, |k, _ops| cm.profile_default(k).duration_us) {
                    Decision::Launch(p) => {
                        w.issue(&p.ops);
                        for id in p.ops {
                            w.complete(id);
                        }
                    }
                    Decision::Wait { until_us } => now = until_us,
                    Decision::Idle => break,
                }
            }
        });
        t.row(vec![
            "submit+decide+drain".into(),
            format!("window={n}"),
            f(timing.median_us, 1),
            f(timing.median_us / n as f64, 2),
        ]);
    }

    // (b) pure packing throughput
    let mut rng = Rng::new(9);
    let ops: Vec<TensorOp> = (0..256)
        .map(|i| TensorOp {
            id: OpId(i),
            stream: StreamId(i as u32),
            seq: 0,
            kernel: mixed_kernel(&mut rng),
            arrival_us: 0.0,
            deadline_us: 1e9,
            group: 0,
            tag: 0,
            independent: false,
        })
        .collect();
    let refs: Vec<&TensorOp> = ops.iter().collect();
    let coal = Coalescer::default();
    let timing = time_it(5, 50, || {
        std::hint::black_box(coal.pack(&refs));
    });
    t.row(vec![
        "coalescer.pack".into(),
        "256 ops".into(),
        f(timing.median_us, 1),
        f(timing.median_us / 256.0, 3),
    ]);

    // (c) manifest parse
    if let Ok(m) = Manifest::load_default() {
        let dir = m.dir.clone();
        let timing = time_it(2, 20, || {
            std::hint::black_box(Manifest::load(&dir).unwrap());
        });
        t.row(vec![
            "manifest parse".into(),
            "manifest.json".into(),
            f(timing.median_us, 0),
            String::new(),
        ]);
    }

    // (d) PJRT dispatch overhead: smallest super artifact, repeated
    if let Ok(mut ex) = PjrtExecutor::from_default_artifacts() {
        use vliw_jit::compiler::coalescer::{ShapeClass, SuperKernel};
        use vliw_jit::compiler::jit::KernelExecutor;
        let k = KernelDesc::batched(1, 32, 256, 256);
        let sk = SuperKernel {
            class: ShapeClass { m: 32, k: 256, n: 256 },
            ops: vec![],
            useful_flops: k.flops(),
            kernel: k,
        };
        let _ = ex.execute(&sk); // warm compile
        let timing = time_it(3, 30, || {
            std::hint::black_box(ex.execute(&sk));
        });
        // pure-compute estimate for the same GEMM from the flops prior:
        t.row(vec![
            "pjrt super_A_p1 exec".into(),
            format!("{:.1} MFLOP", k.flops() / 1e6),
            f(timing.median_us, 0),
            String::new(),
        ]);
    }

    t.emit();
    println!("targets: decide+drain < 10 µs/op @ window<=256; pack < 1 µs/op;");
    println!("manifest parse off request path; dispatch overhead bounded by exec time.");
}
