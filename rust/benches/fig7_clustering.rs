//! Figure 7: GEMM shapes across popular DNNs concentrate into a few
//! clusters; within a cluster, problems coalesce with minimal padding.
//!
//! Reproduction: k-means over every GEMM in the 12-model zoo (log-shape
//! space), plus the exact power-of-two coalescing-class histogram the
//! runtime actually packs by. Clusters A/B/C = the three largest.

use vliw_jit::bench::{f, Table};
use vliw_jit::compiler::cluster::{class_histogram, kmeans, wcss};
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::model::zoo::zoo;

fn main() {
    let kernels: Vec<KernelDesc> = zoo().iter().flat_map(|m| m.gemms(1)).collect();
    println!(
        "{} GEMM kernels extracted from {} models\n",
        kernels.len(),
        zoo().len()
    );

    let mut clusters = kmeans(&kernels, 6, 42, 100);
    clusters.sort_by(|a, b| b.size().cmp(&a.size()));
    let mut t = Table::new(
        "Figure 7 — GEMM shape clusters (k-means, log-shape space, k=6)",
        &["cluster", "kernels", "share_%", "centroid_mkn", "repr_class", "mean_pad_%"],
    );
    let total = kernels.len() as f64;
    for (i, c) in clusters.iter().enumerate() {
        let label = ["A", "B", "C", "D", "E", "F"][i];
        t.row(vec![
            label.to_string(),
            c.size().to_string(),
            f(c.size() as f64 / total * 100.0, 1),
            format!(
                "{:.0}x{:.0}x{:.0}",
                c.centroid[0].exp2(),
                c.centroid[1].exp2(),
                c.centroid[2].exp2()
            ),
            format!("{}x{}x{}", c.class.0, c.class.1, c.class.2),
            f(c.mean_padding * 100.0, 1),
        ]);
    }
    t.emit();

    // clustering quality: variance explained by 6 clusters
    let w6 = wcss(&clusters);
    let w1 = wcss(&kmeans(&kernels, 1, 42, 100));
    println!(
        "variance explained by 6 clusters: {:.1}%  (paper: \"concentrated into several clusters\")",
        (1.0 - w6 / w1) * 100.0
    );

    // exact coalescing classes (what superkernel artifacts get compiled)
    let hist = class_histogram(&kernels);
    let mut t2 = Table::new(
        "Figure 7b — top power-of-two coalescing classes (exact packing classes)",
        &["class_mkn", "kernels", "cum_share_%"],
    );
    let mut cum = 0usize;
    for ((m, k, n), cnt) in hist.iter().take(10) {
        cum += cnt;
        t2.row(vec![
            format!("{m}x{k}x{n}"),
            cnt.to_string(),
            f(cum as f64 / total * 100.0, 1),
        ]);
    }
    t2.emit();
    let top3: usize = clusters.iter().take(3).map(|c| c.size()).sum();
    println!(
        "top-3 clusters (A,B,C) hold {:.0}% of all kernels; mean within-cluster padding of A/B/C: {:.1}%",
        top3 as f64 / total * 100.0,
        clusters
            .iter()
            .take(3)
            .map(|c| c.mean_padding)
            .sum::<f64>()
            / 3.0
            * 100.0
    );
    println!(
        "reproduced: {}",
        if top3 as f64 / total > 0.5 {
            "YES (problems concentrate; A/B/C coalesce with bounded padding)"
        } else {
            "PARTIAL"
        }
    );
}
