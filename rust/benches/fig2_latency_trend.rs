//! Figure 2: DNN model complexity and batch-1 inference latency over model
//! generations, CPU vs GPU, against the 300 ms interactive SLO.
//!
//! Paper claims reproduced (shape): latency grows across model
//! generations; most modern models miss 300 ms on CPU (SENet-class takes
//! seconds); every zoo model fits comfortably on a V100.

use vliw_jit::bench::{f, ms, Table};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::device::DeviceSpec;
use vliw_jit::model::zoo::zoo;

fn batch1_latency_us(cm: &CostModel, layers: &[vliw_jit::gpu::kernel::KernelDesc]) -> f64 {
    layers
        .iter()
        .map(|k| cm.profile_default(k).duration_us + cm.device.layer_overhead_us)
        .sum()
}

fn main() {
    let cpu = CostModel::new(DeviceSpec::cpu_xeon());
    let gpu = CostModel::v100();
    let slo_us = 300_000.0;

    let mut t = Table::new(
        "Figure 2 — batch-1 latency by model generation (CPU vs V100, 300 ms SLO)",
        &["model", "year", "GFLOP", "kernels", "cpu_ms", "gpu_ms", "cpu_SLO", "gpu_SLO"],
    );
    let mut cpu_misses = 0;
    let mut gpu_misses = 0;
    let mut models = zoo();
    models.sort_by_key(|m| (m.year, m.name));
    let n_models = models.len();
    for m in &models {
        let layers = m.gemms(1);
        let lc = batch1_latency_us(&cpu, &layers);
        let lg = batch1_latency_us(&gpu, &layers);
        if lc > slo_us {
            cpu_misses += 1;
        }
        if lg > slo_us {
            gpu_misses += 1;
        }
        t.row(vec![
            m.name.to_string(),
            m.year.to_string(),
            f(m.flops() / 1e9, 1),
            layers.len().to_string(),
            ms(lc),
            ms(lg),
            if lc <= slo_us { "ok" } else { "MISS" }.into(),
            if lg <= slo_us { "ok" } else { "MISS" }.into(),
        ]);
    }
    t.emit();

    println!(
        "summary: {cpu_misses}/{n} models miss the 300 ms SLO on CPU; {gpu_misses}/{n} on V100",
        n = n_models
    );
    println!("paper: \"Most models fail to meet the 300ms latency SLO on a CPU\"");
    println!(
        "reproduced: {}",
        if cpu_misses * 2 >= n_models && gpu_misses == 0 {
            "YES (CPU majority-miss, GPU all-hit)"
        } else {
            "PARTIAL — see EXPERIMENTS.md"
        }
    );
}
