//! Bit-exact mirror of the python deterministic generators
//! (`python/compile/model.py`: `hash01`, `fnv1a`) and golden-check helpers.
//!
//! The AOT manifest records, per artifact, the expected output prefix for a
//! `hash01`-generated input. Because the generator is pure integer
//! arithmetic, the rust runtime regenerates identical inputs and verifies
//! the *whole* path — manifest → HLO → PJRT compile → execute — against the
//! python reference numerics without shipping tensors.

/// `hash01(idx, base)`: deterministic uniform f32 in [-1, 1).
/// Mirrors `compile.model.hash01` exactly (tests pin shared literals).
pub fn hash01(idx: u64, base: u64) -> f32 {
    const KNUTH: u64 = 2654435761;
    const MOD: u64 = 0xFFFF_FFFF;
    let i = idx.wrapping_add(base).wrapping_add(1);
    let mut u = i.wrapping_mul(KNUTH) & MOD;
    u = ((u ^ (u >> 13)).wrapping_mul(0x5BD1_E995)) & MOD;
    u ^= u >> 15;
    (u as f64 / 2147483648.0 - 1.0) as f32
}

/// Fill a buffer with the hash01 stream starting at `base`.
pub fn fill_hash01(out: &mut [f32], base: u64) {
    for (i, v) in out.iter_mut().enumerate() {
        *v = hash01(i as u64, base);
    }
}

/// Allocate and fill.
pub fn gen_hash01(n: usize, base: u64) -> Vec<f32> {
    let mut v = vec![0.0; n];
    fill_hash01(&mut v, base);
    v
}

/// FNV-1a 32-bit (per-tensor weight seed base in python).
pub fn fnv1a(s: &str) -> u32 {
    let mut h: u32 = 2166136261;
    for b in s.as_bytes() {
        h = (h ^ *b as u32).wrapping_mul(16777619);
    }
    h
}

/// hash01 stream bases used for superkernel golden inputs
/// (`compile.aot.SUPER_A_BASE` / `SUPER_B_BASE`).
pub const SUPER_A_BASE: u64 = 0;
/// Right-operand stream base.
pub const SUPER_B_BASE: u64 = 1 << 20;

/// Compare the first `prefix.len()` outputs and the mean|x| against a
/// manifest golden entry. Returns the max relative error on the prefix.
pub fn check_prefix(out: &[f32], prefix: &[f64], mean_abs: f64, tol: f64) -> Result<f64, String> {
    if out.len() < prefix.len() {
        return Err(format!(
            "output too short: {} < {}",
            out.len(),
            prefix.len()
        ));
    }
    let mut max_rel = 0.0f64;
    for (i, (&o, &g)) in out.iter().zip(prefix.iter()).enumerate() {
        let denom = g.abs().max(1e-3);
        let rel = ((o as f64 - g).abs()) / denom;
        if rel > tol {
            return Err(format!("output[{i}] = {o} vs golden {g} (rel {rel:.2e})"));
        }
        max_rel = max_rel.max(rel);
    }
    let got_mean = out.iter().map(|v| v.abs() as f64).sum::<f64>() / out.len() as f64;
    let mean_rel = (got_mean - mean_abs).abs() / mean_abs.max(1e-9);
    if mean_rel > tol {
        return Err(format!(
            "mean|out| = {got_mean:.6} vs golden {mean_abs:.6} (rel {mean_rel:.2e})"
        ));
    }
    Ok(max_rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash01_matches_python_literals() {
        // pinned in python/tests/test_model.py::test_hash01_golden_values
        let expect = [
            0.195082441f32,
            0.706475973,
            -0.552727699,
            -0.869781792,
            -0.42700702,
            0.493466735,
        ];
        for (i, e) in expect.iter().enumerate() {
            let got = hash01(i as u64, 0);
            assert!((got - e).abs() < 1e-6, "idx {i}: {got} vs {e}");
        }
        let expect_b = [-0.365425706f32, -0.783480048, -0.861492336];
        for (i, e) in expect_b.iter().enumerate() {
            let got = hash01(i as u64, 1 << 20);
            assert!((got - e).abs() < 1e-6, "idx {i}: {got} vs {e}");
        }
    }

    #[test]
    fn fnv1a_matches_python() {
        assert_eq!(fnv1a("mlp_small.w0"), 1396747245);
    }

    #[test]
    fn stream_is_roughly_uniform() {
        let v = gen_hash01(100_000, 0);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn check_prefix_accepts_and_rejects() {
        let out = [1.0f32, 2.0, 3.0];
        assert!(check_prefix(&out, &[1.0, 2.0, 3.0], 2.0, 1e-4).is_ok());
        assert!(check_prefix(&out, &[1.0, 2.5, 3.0], 2.0, 1e-4).is_err());
        assert!(check_prefix(&out, &[1.0, 2.0, 3.0], 9.0, 1e-4).is_err());
        assert!(check_prefix(&out[..2], &[1.0, 2.0, 3.0], 2.0, 1e-4).is_err());
    }
}
