//! Request-path runtime: load AOT artifacts, execute via PJRT, self-check
//! numerics.
//!
//! Python runs once (`make artifacts`); everything here is pure rust:
//!
//! * [`artifact`] — `manifest.json` model + weight-blob loading;
//! * [`golden`] — bit-exact mirror of the python `hash01`/`fnv1a`
//!   generators, so the runtime can regenerate test inputs and verify
//!   outputs against manifest goldens without shipping tensors;
//! * [`pjrt`] — PJRT CPU client wrapper: HLO text → compiled executable
//!   cache;
//! * [`executor`] — the [`crate::compiler::jit::KernelExecutor`]
//!   implementation over PJRT (real path) plus model-level batched
//!   execution for the serving layer.

pub mod artifact;
pub mod executor;
pub mod golden;
pub mod pjrt;

pub use artifact::Manifest;
pub use executor::PjrtExecutor;
pub use pjrt::PjrtRuntime;
