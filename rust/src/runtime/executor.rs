//! Executors over the PJRT runtime.
//!
//! * [`PjrtExecutor`] implements [`KernelExecutor`] so the OoO VLIW JIT can
//!   launch *real* coalesced superkernels (the AOT-compiled Pallas batched
//!   GEMM) — the paper's proposal running end-to-end on actual compiled
//!   code.
//! * Model-level batched execution ([`PjrtExecutor::execute_model`]) backs
//!   the serving layer: requests padded into the smallest compiled batch
//!   variant, weights resident (loaded once, passed per call).
//!
//! Latency estimates are *learned online* — the §5.2 "monitoring
//! inference latencies per-kernel" loop — through the crate-wide
//! estimation substrate in [`crate::estimate`]: a per-artifact
//! [`Measured`] EWMA bank (smoothing factor from
//! `compiler::scheduler::Policy::ewma_alpha`), falling back to a
//! FLOPS-proportional prior before the first observation. The serving
//! layer's full three-tier (Measured/Tuned/Prior) resolution lives in
//! [`crate::estimate::TieredEstimator`]; this executor is the
//! artifact-level Measured tier that feeds it.

use std::collections::HashMap;

use crate::compiler::coalescer::SuperKernel;
use crate::compiler::jit::KernelExecutor;
use crate::compiler::scheduler::Policy;
use crate::estimate::Measured;
use crate::gpu::kernel::KernelDesc;
use crate::runtime::artifact::{Manifest, SuperArtifact};
use crate::runtime::golden;
use crate::runtime::pjrt::{HostTensor, PjrtRuntime};
use crate::{Error, Result};

/// Result of a batched model execution.
#[derive(Debug, Clone)]
pub struct ModelExec {
    /// Per-request outputs (d_out each), in input order.
    pub outputs: Vec<Vec<f32>>,
    /// Executed batch (padded variant size).
    pub batch: u32,
    /// Wall time, µs.
    pub duration_us: f64,
}

/// Real executor: PJRT CPU over the AOT artifact set.
pub struct PjrtExecutor {
    rt: PjrtRuntime,
    manifest: Manifest,
    /// weights per model, converted to HostTensors once
    weights: HashMap<String, Vec<HostTensor>>,
    /// learned per-artifact latency (file -> EWMA µs), the Measured tier
    est: Measured<String>,
    /// FLOPS prior for unseen artifacts (CPU-PJRT effective GEMM rate).
    pub prior_gflops: f64,
    /// total executions (diagnostics)
    pub executions: u64,
}

impl PjrtExecutor {
    /// Build over a manifest (loads nothing eagerly except the client).
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(PjrtExecutor {
            rt: PjrtRuntime::cpu()?,
            manifest,
            weights: HashMap::new(),
            est: Measured::new(Policy::default().ewma_alpha),
            prior_gflops: 5.0,
            executions: 0,
        })
    }

    /// Load from the default artifact location.
    pub fn from_default_artifacts() -> Result<Self> {
        Self::new(Manifest::load_default()?)
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pre-compile every artifact of a model (+ cache weights): serving
    /// never compiles on the request path.
    pub fn warmup_model(&mut self, model: &str) -> Result<f64> {
        let files: Vec<String> = self
            .manifest
            .model(model)?
            .artifacts
            .iter()
            .map(|a| a.file.clone())
            .collect();
        let mut total = 0.0;
        for f in files {
            total += self.rt.warmup(&self.manifest.path_of(&f))?;
        }
        self.ensure_weights(model)?;
        Ok(total)
    }

    /// Pre-compile every superkernel artifact.
    pub fn warmup_supers(&mut self) -> Result<f64> {
        let files: Vec<String> = self.manifest.supers.iter().map(|s| s.file.clone()).collect();
        let mut total = 0.0;
        for f in files {
            total += self.rt.warmup(&self.manifest.path_of(&f))?;
        }
        Ok(total)
    }

    fn ensure_weights(&mut self, model: &str) -> Result<()> {
        if self.weights.contains_key(model) {
            return Ok(());
        }
        let loaded = self.manifest.load_weights(model)?;
        let tensors = loaded
            .into_iter()
            .map(|(w, vals)| {
                HostTensor::new(vals, w.shape.iter().map(|&d| d as i64).collect())
            })
            .collect::<Result<Vec<_>>>()?;
        self.weights.insert(model.to_string(), tensors);
        Ok(())
    }

    /// Execute a batch of requests (each a `d_in` vector) through the
    /// smallest compiled variant that fits, zero-padding the tail.
    pub fn execute_model(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        if rows.is_empty() {
            return Err(Error::config("empty batch"));
        }
        self.ensure_weights(model)?;
        let entry = self.manifest.model(model)?;
        let d_in = entry.d_in as usize;
        let d_out = entry.d_out as usize;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != d_in {
                return Err(Error::config(format!(
                    "row {i}: {} features, model wants {d_in}",
                    r.len()
                )));
            }
        }
        let art = entry.variant_for(rows.len() as u32).ok_or_else(|| {
            Error::Artifact(format!(
                "batch {} exceeds max compiled variant {} for {model}",
                rows.len(),
                entry.max_batch()
            ))
        })?;
        let variant_batch = art.batch;
        let batch = art.batch as usize;
        let file = art.file.clone();
        drop(entry);
        // marshal [batch, d_in] with zero padding
        let mut x = vec![0.0f32; batch * d_in];
        for (i, r) in rows.iter().enumerate() {
            x[i * d_in..(i + 1) * d_in].copy_from_slice(r);
        }
        let mut inputs = vec![HostTensor::new(x, vec![batch as i64, d_in as i64])?];
        inputs.extend(self.weights.get(model).expect("ensured").iter().cloned());
        let out = self.rt.execute(&self.manifest.path_of(&file), &inputs)?;
        self.observe(&file, out.duration_us);
        self.executions += 1;
        let outputs = rows
            .iter()
            .enumerate()
            .map(|(i, _)| out.data[i * d_out..(i + 1) * d_out].to_vec())
            .collect();
        Ok(ModelExec {
            outputs,
            batch: variant_batch,
            duration_us: out.duration_us,
        })
    }

    /// Golden self-check of a (model, batch) artifact: regenerate the
    /// hash01 input, execute, compare to the manifest golden. Returns max
    /// relative error.
    pub fn golden_check_model(&mut self, model: &str, batch: u32) -> Result<f64> {
        self.ensure_weights(model)?;
        let entry = self.manifest.model(model)?;
        let d_in = entry.d_in as usize;
        let art = entry
            .artifacts
            .iter()
            .find(|a| a.batch == batch)
            .ok_or_else(|| Error::Artifact(format!("no batch-{batch} variant")))?;
        let golden_data = art.golden.clone();
        let file = art.file.clone();
        let b = batch as usize;
        let x = HostTensor::new(
            golden::gen_hash01(b * d_in, 0),
            vec![b as i64, d_in as i64],
        )?;
        let mut inputs = vec![x];
        inputs.extend(self.weights.get(model).expect("ensured").iter().cloned());
        let out = self.rt.execute(&self.manifest.path_of(&file), &inputs)?;
        golden::check_prefix(
            &out.data,
            &golden_data.out_prefix,
            golden_data.out_mean_abs,
            2e-3,
        )
        .map_err(Error::Artifact)
    }

    /// Execute a superkernel artifact with hash01 payloads and verify its
    /// golden. Returns max relative error.
    pub fn golden_check_super(&mut self, s: &SuperArtifact) -> Result<f64> {
        let (p, m, k, n) = (
            s.problems as usize,
            s.m as usize,
            s.k as usize,
            s.n as usize,
        );
        let a = HostTensor::new(
            golden::gen_hash01(p * m * k, golden::SUPER_A_BASE),
            vec![p as i64, m as i64, k as i64],
        )?;
        let b = HostTensor::new(
            golden::gen_hash01(p * k * n, golden::SUPER_B_BASE),
            vec![p as i64, k as i64, n as i64],
        )?;
        let out = self.rt.execute(&self.manifest.path_of(&s.file), &[a, b])?;
        golden::check_prefix(&out.data, &s.golden.out_prefix, s.golden.out_mean_abs, 1e-3)
            .map_err(Error::Artifact)
    }

    fn observe(&mut self, file: &str, us: f64) {
        self.est.observe(file.to_string(), us);
    }

    /// Learned per-artifact estimate, falling back to the FLOPS prior only
    /// while the artifact has never been observed (the estimator's
    /// observation count — not a 0-value sentinel — decides; a genuine
    /// ~0 µs measurement is a valid estimate).
    pub(crate) fn estimate_file(&self, file: &str, flops: f64) -> f64 {
        self.est
            .estimate_or(&file.to_string(), || flops / (self.prior_gflops * 1e3)) // µs
    }

    /// Find the superkernel artifact a batched kernel maps to.
    pub fn super_artifact_for(&self, k: &KernelDesc) -> Option<&SuperArtifact> {
        self.manifest.super_for(k.m, k.k, k.n, k.problems)
    }
}

impl KernelExecutor for PjrtExecutor {
    fn estimate_us(&self, k: &KernelDesc) -> f64 {
        match self.super_artifact_for(k) {
            Some(s) => {
                let padded = KernelDesc::batched(s.problems, s.m, s.k, s.n);
                self.estimate_file(&s.file, padded.flops())
            }
            None => k.flops() / (self.prior_gflops * 1e3),
        }
    }

    /// Execute a coalesced pack on the matching superkernel artifact:
    /// problems zero-padded up to the artifact capacity, payloads hash01
    /// (real data movement + compute; outputs validated by goldens in
    /// tests). Returns measured wall µs.
    fn execute(&mut self, sk: &SuperKernel) -> f64 {
        let Some(s) = self.super_artifact_for(&sk.kernel) else {
            // no artifact for this class: charge the FLOPS-prior estimate
            // (simulated fallback keeps the JIT total)
            return self.estimate_us(&sk.kernel);
        };
        let (p, m, k, n) = (
            s.problems as usize,
            s.m as usize,
            s.k as usize,
            s.n as usize,
        );
        let file = s.file.clone();
        let a = HostTensor::new(golden::gen_hash01(p * m * k, 0), vec![
            p as i64, m as i64, k as i64,
        ])
        .expect("shape ok");
        let b = HostTensor::new(golden::gen_hash01(p * k * n, 1 << 20), vec![
            p as i64, k as i64, n as i64,
        ])
        .expect("shape ok");
        match self.rt.execute(&self.manifest.path_of(&file), &[a, b]) {
            Ok(out) => {
                self.observe(&file, out.duration_us);
                self.executions += 1;
                out.duration_us
            }
            Err(e) => {
                crate::util::logging::emit(
                    crate::util::logging::Level::Error,
                    format_args!("superkernel exec failed: {e}"),
                );
                self.estimate_us(&sk.kernel)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec() -> PjrtExecutor {
        PjrtExecutor::from_default_artifacts().expect("make artifacts")
    }

    #[test]
    fn model_execution_pads_and_splits() {
        let mut e = exec();
        let rows: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * (i as f32 + 1.0); 256]).collect();
        let r = e.execute_model("mlp_small", &rows).unwrap();
        assert_eq!(r.batch, 4, "3 rows pad to the 4-batch variant");
        assert_eq!(r.outputs.len(), 3);
        assert!(r.outputs.iter().all(|o| o.len() == 64));
        assert!(r.duration_us > 0.0);
        // identical inputs must give identical outputs (padding no-leak)
        let again = e.execute_model("mlp_small", &rows).unwrap();
        assert_eq!(r.outputs, again.outputs);
    }

    #[test]
    fn batch_padding_does_not_change_results() {
        // one row alone vs same row in a padded batch: same output
        let mut e = exec();
        let row = vec![0.25f32; 256];
        let solo = e.execute_model("mlp_small", &[row.clone()]).unwrap();
        let padded = e
            .execute_model("mlp_small", &[row.clone(), vec![0.5; 256], row])
            .unwrap();
        for (a, b) in solo.outputs[0].iter().zip(&padded.outputs[0]) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn model_goldens_pass_end_to_end() {
        // full cross-language numerics: python jnp reference == rust PJRT
        let mut e = exec();
        for (model, batch) in [("mlp_small", 1), ("mlp_small", 8), ("gemmnet6", 4)] {
            let err = e.golden_check_model(model, batch).unwrap();
            assert!(err < 2e-3, "{model} b{batch}: rel err {err}");
        }
    }

    #[test]
    fn super_goldens_pass_all_classes() {
        let mut e = exec();
        let supers = e.manifest().supers.clone();
        // check one per class (the full sweep runs in integration tests)
        for class in ["A", "B", "C"] {
            let s = supers.iter().find(|s| s.class == class).unwrap();
            let err = e.golden_check_super(s).unwrap();
            assert!(err < 1e-3, "class {class}: rel err {err}");
        }
    }

    #[test]
    fn jit_executes_real_superkernels() {
        use crate::compiler::ir::{DispatchRequest, StreamId};
        use crate::compiler::jit::{JitCompiler, JitConfig};
        // 4 streams issue class-A-shaped GEMMs; the JIT must coalesce them
        // into ONE launch of the real super_A_p4 artifact
        let mut jit = JitCompiler::new(JitConfig::default(), exec());
        let ops: Vec<(f64, DispatchRequest)> = (0..4)
            .map(|s| {
                (
                    0.0,
                    DispatchRequest::new(
                        StreamId(s),
                        KernelDesc::gemm(32, 256, 256),
                        5_000_000.0,
                    ),
                )
            })
            .collect();
        let done = jit.run_trace(ops);
        assert_eq!(done.len(), 4);
        assert_eq!(jit.stats.launches, 1);
        assert_eq!(jit.executor().executions, 1);
        assert!(done.iter().all(|c| c.pack_size == 4));
    }

    #[test]
    fn estimates_learn_from_observations() {
        let mut e = exec();
        let k = KernelDesc::batched(2, 32, 256, 256);
        let prior = e.estimate_us(&k);
        // execute once; the EWMA should take over
        let sk = SuperKernel {
            class: crate::compiler::coalescer::ShapeClass {
                m: 32,
                k: 256,
                n: 256,
            },
            ops: vec![],
            useful_flops: k.flops(),
            kernel: k,
        };
        let measured = e.execute(&sk);
        let post = e.estimate_us(&k);
        assert!(measured > 0.0);
        assert!(
            (post - measured).abs() / measured < 0.5,
            "estimate {post} should track measurement {measured} (prior {prior})"
        );
    }

    #[test]
    fn zero_observation_overrides_prior() {
        // regression: a genuine ~0 µs measurement must beat the FLOPS
        // prior, not be mistaken for "never observed"
        let mut e = exec();
        e.observe("synthetic_artifact", 0.0);
        assert_eq!(e.estimate_file("synthetic_artifact", 1e9), 0.0);
        let prior = e.estimate_file("unseen_artifact", 1e9);
        assert!(prior > 0.0, "unseen artifacts still use the prior");
    }

    #[test]
    fn oversized_batch_is_clean_error() {
        let mut e = exec();
        let rows: Vec<Vec<f32>> = (0..64).map(|_| vec![0.0; 256]).collect();
        let err = e.execute_model("mlp_small", &rows).unwrap_err();
        assert!(format!("{err}").contains("exceeds max"));
    }

    #[test]
    fn wrong_feature_count_is_clean_error() {
        let mut e = exec();
        let err = e.execute_model("mlp_small", &[vec![0.0; 100]]).unwrap_err();
        assert!(format!("{err}").contains("features"));
    }
}
