//! Artifact manifest: the contract between the python compile path and the
//! rust request path (`artifacts/manifest.json`, written by `compile.aot`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Golden self-check data for one artifact.
#[derive(Debug, Clone)]
pub struct Golden {
    /// First 8 output values (flattened).
    pub out_prefix: Vec<f64>,
    /// Mean |output|.
    pub out_mean_abs: f64,
}

/// One weight tensor inside a model's weight blob.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    /// Tensor name ("mlp_small.w0").
    pub name: String,
    /// Shape.
    pub shape: Vec<usize>,
    /// Byte offset in the blob.
    pub offset_bytes: usize,
    /// Byte length.
    pub nbytes: usize,
}

/// One (model, batch) compiled variant.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// Batch size this executable was lowered for.
    pub batch: u32,
    /// HLO text file name.
    pub file: String,
    /// Golden check.
    pub golden: Golden,
}

/// A model entry.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Model name.
    pub name: String,
    /// "mlp" | "gemmnet".
    pub kind: String,
    /// Input features.
    pub d_in: u32,
    /// Output features.
    pub d_out: u32,
    /// Parameter count.
    pub params: u64,
    /// FLOPs per query.
    pub flops_per_query: u64,
    /// Weight blob file.
    pub weights_file: String,
    /// Weight table.
    pub weights: Vec<WeightEntry>,
    /// Batch variants (ascending batch).
    pub artifacts: Vec<ModelArtifact>,
}

impl ModelEntry {
    /// Smallest compiled batch ≥ `n` (the batcher's pad-up rule).
    pub fn variant_for(&self, n: u32) -> Option<&ModelArtifact> {
        self.artifacts.iter().find(|a| a.batch >= n)
    }

    /// Largest compiled batch (batcher's chunk size under load).
    pub fn max_batch(&self) -> u32 {
        self.artifacts.iter().map(|a| a.batch).max().unwrap_or(1)
    }
}

/// One compiled superkernel variant.
#[derive(Debug, Clone)]
pub struct SuperArtifact {
    /// Shape class label ("A"/"B"/"C").
    pub class: String,
    /// Per-problem rows.
    pub m: u32,
    /// Contraction depth.
    pub k: u32,
    /// Per-problem cols.
    pub n: u32,
    /// Capacity (problems packed).
    pub problems: u32,
    /// HLO text file name.
    pub file: String,
    /// Golden check.
    pub golden: Golden,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory containing the artifacts.
    pub dir: PathBuf,
    /// Models by name.
    pub models: HashMap<String, ModelEntry>,
    /// Superkernels (all classes/capacities).
    pub supers: Vec<SuperArtifact>,
}

fn parse_golden(j: &Json) -> Result<Golden> {
    let prefix = j
        .req("out_prefix")?
        .as_arr()
        .ok_or_else(|| Error::Json("out_prefix not an array".into()))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| Error::Json("non-number in prefix".into())))
        .collect::<Result<Vec<f64>>>()?;
    Ok(Golden {
        out_prefix: prefix,
        out_mean_abs: j.req_f64("out_mean_abs")?,
    })
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        if j.req_u64("version")? != 1 {
            return Err(Error::Artifact("unsupported manifest version".into()));
        }
        let mut models = HashMap::new();
        for m in j.req("models")?.as_arr().unwrap_or(&[]) {
            let mut weights = Vec::new();
            for w in m.req("weights")?.as_arr().unwrap_or(&[]) {
                weights.push(WeightEntry {
                    name: w.req_str("name")?,
                    shape: w
                        .req("shape")?
                        .as_arr()
                        .ok_or_else(|| Error::Json("shape not array".into()))?
                        .iter()
                        .map(|v| v.as_u64().map(|x| x as usize))
                        .collect::<Option<Vec<usize>>>()
                        .ok_or_else(|| Error::Json("bad shape".into()))?,
                    offset_bytes: m_usize(w, "offset_bytes")?,
                    nbytes: m_usize(w, "nbytes")?,
                });
            }
            let mut artifacts = Vec::new();
            for a in m.req("artifacts")?.as_arr().unwrap_or(&[]) {
                artifacts.push(ModelArtifact {
                    batch: a.req_u64("batch")? as u32,
                    file: a.req_str("file")?,
                    golden: parse_golden(a.req("golden")?)?,
                });
            }
            artifacts.sort_by_key(|a| a.batch);
            let entry = ModelEntry {
                name: m.req_str("name")?,
                kind: m.req_str("kind")?,
                d_in: m.req_u64("d_in")? as u32,
                d_out: m.req_u64("d_out")? as u32,
                params: m.req_u64("params")?,
                flops_per_query: m.req_u64("flops_per_query")?,
                weights_file: m.req_str("weights_file")?,
                weights,
                artifacts,
            };
            models.insert(entry.name.clone(), entry);
        }
        let mut supers = Vec::new();
        for s in j.req("supers")?.as_arr().unwrap_or(&[]) {
            supers.push(SuperArtifact {
                class: s.req_str("class")?,
                m: s.req_u64("m")? as u32,
                k: s.req_u64("k")? as u32,
                n: s.req_u64("n")? as u32,
                problems: s.req_u64("problems")? as u32,
                file: s.req_str("file")?,
                golden: parse_golden(s.req("golden")?)?,
            });
        }
        Ok(Manifest {
            dir,
            models,
            supers,
        })
    }

    /// Load from the repo-default location (`$CARGO_MANIFEST_DIR/artifacts`
    /// or `./artifacts`).
    pub fn load_default() -> Result<Manifest> {
        let candidates = [
            std::env::var("VLIW_ARTIFACTS").unwrap_or_default(),
            format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
            "artifacts".to_string(),
        ];
        for c in candidates.iter().filter(|c| !c.is_empty()) {
            if Path::new(c).join("manifest.json").exists() {
                return Self::load(c);
            }
        }
        Err(Error::Artifact(
            "no artifacts/manifest.json found; run `make artifacts`".into(),
        ))
    }

    /// A model by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown model '{name}'")))
    }

    /// Smallest-capacity superkernel of class (m,k,n) with `problems ≥ p`.
    pub fn super_for(&self, m: u32, k: u32, n: u32, p: u32) -> Option<&SuperArtifact> {
        self.supers
            .iter()
            .filter(|s| s.m == m && s.k == k && s.n == n && s.problems >= p)
            .min_by_key(|s| s.problems)
    }

    /// All superkernel classes present: (class, m, k, n, max problems).
    pub fn super_classes(&self) -> Vec<(String, u32, u32, u32, u32)> {
        let mut out: Vec<(String, u32, u32, u32, u32)> = Vec::new();
        for s in &self.supers {
            if let Some(e) = out.iter_mut().find(|e| e.0 == s.class) {
                e.4 = e.4.max(s.problems);
            } else {
                out.push((s.class.clone(), s.m, s.k, s.n, s.problems));
            }
        }
        out
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a model's weight tensors as flat f32 vectors (in ABI order).
    pub fn load_weights(&self, model: &str) -> Result<Vec<(WeightEntry, Vec<f32>)>> {
        let entry = self.model(model)?;
        let blob = std::fs::read(self.path_of(&entry.weights_file))?;
        entry
            .weights
            .iter()
            .map(|w| {
                let end = w.offset_bytes + w.nbytes;
                if end > blob.len() {
                    return Err(Error::Artifact(format!(
                        "weight {} out of range: {}..{end} > {}",
                        w.name,
                        w.offset_bytes,
                        blob.len()
                    )));
                }
                let raw = &blob[w.offset_bytes..end];
                let vals: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let expect: usize = w.shape.iter().product();
                if vals.len() != expect {
                    return Err(Error::Artifact(format!(
                        "weight {}: {} values, shape wants {expect}",
                        w.name,
                        vals.len()
                    )));
                }
                Ok((w.clone(), vals))
            })
            .collect()
    }
}

fn m_usize(j: &Json, key: &str) -> Result<usize> {
    Ok(j.req_u64(key)? as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::golden;

    fn manifest() -> Manifest {
        Manifest::load_default().expect("artifacts built (make artifacts)")
    }

    #[test]
    fn loads_all_models_and_supers() {
        let m = manifest();
        assert_eq!(m.models.len(), 3);
        for name in ["mlp_small", "mlp_large", "gemmnet6"] {
            let e = m.model(name).unwrap();
            assert!(!e.artifacts.is_empty());
            assert!(e.params > 0 && e.flops_per_query > 0);
        }
        assert_eq!(m.supers.len(), 11);
    }

    #[test]
    fn variant_pad_up_rule() {
        let m = manifest();
        let e = m.model("mlp_small").unwrap();
        assert_eq!(e.variant_for(1).unwrap().batch, 1);
        assert_eq!(e.variant_for(3).unwrap().batch, 4);
        assert_eq!(e.variant_for(17).unwrap().batch, 32);
        assert!(e.variant_for(1000).is_none());
        assert_eq!(e.max_batch(), 32);
    }

    #[test]
    fn super_lookup() {
        let m = manifest();
        let s = m.super_for(32, 256, 256, 3).unwrap();
        assert_eq!(s.problems, 4);
        assert_eq!(s.class, "A");
        assert!(m.super_for(32, 256, 256, 100).is_none());
        assert!(m.super_for(999, 999, 999, 1).is_none());
        let classes = m.super_classes();
        assert_eq!(classes.len(), 3);
    }

    #[test]
    fn weights_load_and_match_generator() {
        let m = manifest();
        let ws = m.load_weights("mlp_small").unwrap();
        assert_eq!(ws.len(), 6);
        let (w0, vals) = &ws[0];
        assert_eq!(w0.name, "mlp_small.w0");
        assert_eq!(w0.shape, vec![256, 256]);
        // python: gen_weight seeds hash01 with fnv1a(name), scale sqrt(3/fan_in)
        let scale = (3.0f64 / 256.0).sqrt() as f32;
        let expect0 = golden::hash01(0, golden::fnv1a("mlp_small.w0") as u64) * scale;
        assert!((vals[0] - expect0).abs() < 1e-6, "{} vs {expect0}", vals[0]);
    }

    #[test]
    fn goldens_present_and_finite() {
        let m = manifest();
        for e in m.models.values() {
            for a in &e.artifacts {
                assert_eq!(a.golden.out_prefix.len(), 8);
                assert!(a.golden.out_prefix.iter().all(|v| v.is_finite()));
                assert!(a.golden.out_mean_abs > 0.0);
            }
        }
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }
}
