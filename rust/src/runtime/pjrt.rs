//! PJRT CPU client wrapper: HLO text → compiled executable cache → execute.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py`): the
//! text parser reassigns instruction ids, avoiding the 64-bit-id proto
//! incompatibility between jax ≥ 0.5 and xla_extension 0.5.1.
//!
//! One `PjrtRuntime` owns the process-wide PJRT client and a compile cache:
//! each artifact is compiled exactly once (at first use or via
//! [`PjrtRuntime::warmup`]) and reused across the serving loop — compile
//! time never sits on the request path.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::{Error, Result};

/// An input tensor for execution: flat f32 data + dims.
#[derive(Debug, Clone)]
pub struct HostTensor {
    /// Row-major f32 data.
    pub data: Vec<f32>,
    /// Dimensions.
    pub dims: Vec<i64>,
}

impl HostTensor {
    /// New tensor (checks element count).
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Result<Self> {
        let n: i64 = dims.iter().product();
        if n as usize != data.len() {
            return Err(Error::config(format!(
                "tensor data {} != dims product {n}",
                data.len()
            )));
        }
        Ok(HostTensor { data, dims })
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.data).reshape(&self.dims)?)
    }
}

/// Result of one execution.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened f32 output (first tuple element).
    pub data: Vec<f32>,
    /// Wall-clock execution time, µs (transfer + compute + readback).
    pub duration_us: f64,
}

/// PJRT CPU runtime with a compile cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime {
            client: xla::PjRtClient::cpu()?,
            cache: HashMap::new(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Compile (once) an HLO-text artifact; returns compile time in µs
    /// (0 when cached).
    pub fn warmup(&mut self, path: &Path) -> Result<f64> {
        let key = path.to_string_lossy().to_string();
        if self.cache.contains_key(&key) {
            return Ok(0.0);
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::config("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let dt = t0.elapsed().as_secs_f64() * 1e6;
        self.cache.insert(key, exe);
        Ok(dt)
    }

    /// Execute an artifact with the given inputs; unwraps the 1-tuple
    /// output (artifacts are lowered with `return_tuple=True`).
    pub fn execute(&mut self, path: &Path, inputs: &[HostTensor]) -> Result<ExecOutput> {
        self.warmup(path)?;
        let key = path.to_string_lossy().to_string();
        let exe = self.cache.get(&key).expect("just warmed");
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        Ok(ExecOutput {
            data,
            duration_us: t0.elapsed().as_secs_f64() * 1e6,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;
    use crate::runtime::golden;

    #[test]
    fn host_tensor_validates_dims() {
        assert!(HostTensor::new(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(HostTensor::new(vec![0.0; 5], vec![2, 3]).is_err());
    }

    // Full PJRT round-trip: compile a real superkernel artifact, execute
    // with hash01 inputs, verify against the python-computed golden.
    #[test]
    fn super_a_p2_matches_python_golden() {
        let m = Manifest::load_default().expect("make artifacts");
        let s = m.super_for(32, 256, 256, 2).expect("super_A_p2");
        assert_eq!(s.problems, 2);
        let mut rt = PjrtRuntime::cpu().unwrap();
        let p = s.problems as usize;
        let (mm, kk, nn) = (s.m as usize, s.k as usize, s.n as usize);
        let a = HostTensor::new(
            golden::gen_hash01(p * mm * kk, golden::SUPER_A_BASE),
            vec![p as i64, mm as i64, kk as i64],
        )
        .unwrap();
        let b = HostTensor::new(
            golden::gen_hash01(p * kk * nn, golden::SUPER_B_BASE),
            vec![p as i64, kk as i64, nn as i64],
        )
        .unwrap();
        let out = rt.execute(&m.path_of(&s.file), &[a, b]).unwrap();
        assert_eq!(out.data.len(), p * mm * nn);
        golden::check_prefix(
            &out.data,
            &s.golden.out_prefix,
            s.golden.out_mean_abs,
            1e-3,
        )
        .expect("pjrt output matches python reference");
    }

    #[test]
    fn compile_cache_hits() {
        let m = Manifest::load_default().expect("make artifacts");
        let s = m.super_for(32, 256, 256, 1).unwrap();
        let mut rt = PjrtRuntime::cpu().unwrap();
        let t1 = rt.warmup(&m.path_of(&s.file)).unwrap();
        assert!(t1 > 0.0, "first compile takes time");
        let t2 = rt.warmup(&m.path_of(&s.file)).unwrap();
        assert_eq!(t2, 0.0, "second compile is cached");
        assert_eq!(rt.compiled_count(), 1);
    }
}
