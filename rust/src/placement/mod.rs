//! Device placement: shard model groups across a (heterogeneous) fleet.
//!
//! The paper's late-binding argument cuts both ways: a JIT that binds ops
//! to *launches* late should also bind launches to *devices* late. This
//! module is that layer — the runtime decision of **which device executes
//! which model group**, sitting between the scheduler (which decides
//! *when* a pack launches) and the executors (which run it):
//!
//! * [`topology`] — the fleet: pool workers backed by [`crate::gpu::device::DeviceSpec`]s,
//!   deduplicated into *device classes* (learned service-time estimates
//!   are keyed per class so heterogeneous workers never pollute each
//!   other's estimates);
//! * [`placer`] — initial assignment (cost-aware LPT) and the
//!   [`placer::PlacementTable`] the launch stage consults per launch
//!   (least-loaded replica routing);
//! * [`rebalancer`] — windowed load observation that **replicates** hot
//!   groups onto cooler devices and **migrates** cold groups off
//!   overloaded ones, strict-improvement gated so stationary load cannot
//!   thrash.
//!
//! # The placement / rebalance contract
//!
//! 1. **Totality** — every model group maps to ≥ 1 live worker at every
//!    instant. The placer seeds one replica per group; replication only
//!    adds; migration adds its destination replica before releasing the
//!    source, and the table refuses to drop a last replica. Routing
//!    additionally falls back to group-hash for an unplaced group.
//! 2. **Bounded churn** — at most
//!    [`rebalancer::RebalanceConfig::max_moves_per_window`] placement
//!    changes per observation window, and a migration must strictly lower
//!    the fleet's peak utilization (no A→B→A ping-pong under stationary
//!    load). Replication is idempotent per (group, worker).
//! 3. **Estimate isolation** — executors learn (device class, group,
//!    padded batch) service times; an observation from one class never
//!    updates another class's estimate.
//!
//! Cross-*host* sharding (multiple machines, network transfer costs) is
//! out of scope here and tracked in ROADMAP.

pub mod placer;
pub mod rebalancer;
pub mod topology;

pub use placer::{Placer, PlacementTable};
pub use rebalancer::{RebalanceAction, RebalanceConfig, RebalanceStats, Rebalancer};
pub use topology::{relative_speed, DeviceTopology, WorkerDevice};
