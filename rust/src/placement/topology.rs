//! The serving fleet: N launch-stage workers, each backed by a device.
//!
//! A *worker* is one slot of the `StatefulPool` launch stage (one backend,
//! one execution timeline). A *device class* groups workers on identical
//! hardware: learned service-time estimates are keyed per class, so a
//! t4 observation never pollutes a v100 estimate and vice versa.

use crate::gpu::device::DeviceSpec;
use crate::Result;

/// One worker in the fleet.
#[derive(Debug, Clone)]
pub struct WorkerDevice {
    /// Pool worker index (stable for the run).
    pub worker: usize,
    /// Device backing this worker.
    pub spec: DeviceSpec,
    /// Device-class id: index into [`DeviceTopology::classes`]. Workers on
    /// identical hardware share a class (and learned estimates).
    pub class: u32,
}

/// Relative effective throughput of a device against the V100 reference
/// (peak FLOPS × sustained efficiency). v100 = 1.0, t4 ≈ 0.52, k80 ≈ 0.25.
pub fn relative_speed(spec: &DeviceSpec) -> f64 {
    let reference = DeviceSpec::v100();
    (spec.peak_flops * spec.max_eff) / (reference.peak_flops * reference.max_eff)
}

/// The fleet topology: workers plus the dedup'd device-class table.
#[derive(Debug, Clone)]
pub struct DeviceTopology {
    workers: Vec<WorkerDevice>,
    /// One representative spec per distinct device name; class id = index.
    classes: Vec<DeviceSpec>,
}

impl DeviceTopology {
    /// Topology over an explicit device list (one worker per spec).
    /// Workers with the same spec *name* share a device class.
    pub fn new(specs: Vec<DeviceSpec>) -> Self {
        let mut classes: Vec<DeviceSpec> = Vec::new();
        let workers = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| {
                let class = match classes.iter().position(|c| c.name == spec.name) {
                    Some(c) => c as u32,
                    None => {
                        classes.push(spec.clone());
                        (classes.len() - 1) as u32
                    }
                };
                WorkerDevice {
                    worker: i,
                    spec,
                    class,
                }
            })
            .collect();
        DeviceTopology { workers, classes }
    }

    /// Topology from CLI device names (`v100,t4,...`). Unknown names are a
    /// hard error naming the offender and the valid specs — never a silent
    /// fallback to a default device.
    pub fn from_names(names: &[String]) -> Result<Self> {
        let mut specs = Vec::with_capacity(names.len());
        for n in names {
            specs.push(DeviceSpec::parse(n)?);
        }
        Ok(Self::new(specs))
    }

    /// `n` identical workers (the legacy single-class pool).
    pub fn homogeneous(n: usize, spec: DeviceSpec) -> Self {
        Self::new(vec![spec; n.max(1)])
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// True when the fleet has no workers.
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// All workers.
    pub fn workers(&self) -> &[WorkerDevice] {
        &self.workers
    }

    /// The distinct device classes (class id = index).
    pub fn classes(&self) -> &[DeviceSpec] {
        &self.classes
    }

    /// Device class of a worker.
    pub fn class_of(&self, worker: usize) -> u32 {
        self.workers[worker % self.workers.len()].class
    }

    /// Spec backing a worker.
    pub fn spec_of(&self, worker: usize) -> &DeviceSpec {
        &self.workers[worker % self.workers.len()].spec
    }

    /// Relative speed of a worker's device (v100 = 1.0).
    pub fn speed_of_worker(&self, worker: usize) -> f64 {
        relative_speed(self.spec_of(worker))
    }

    /// Relative speed per device class, indexed by class id.
    pub fn class_speeds(&self) -> Vec<f64> {
        self.classes.iter().map(relative_speed).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_dedupe_by_name() {
        let t = DeviceTopology::new(vec![
            DeviceSpec::v100(),
            DeviceSpec::t4(),
            DeviceSpec::v100(),
        ]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.classes().len(), 2);
        assert_eq!(t.class_of(0), 0);
        assert_eq!(t.class_of(1), 1);
        assert_eq!(t.class_of(2), 0, "second v100 shares the class");
        assert_eq!(t.spec_of(1).name, "t4");
    }

    #[test]
    fn from_names_parses_and_rejects() {
        let t =
            DeviceTopology::from_names(&["v100".to_string(), "t4".to_string()]).unwrap();
        assert_eq!(t.len(), 2);
        let err = DeviceTopology::from_names(&["v100".to_string(), "h100".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("h100"), "names the offender: {err}");
        assert!(err.contains("v100") && err.contains("tpuv2"), "lists specs: {err}");
    }

    #[test]
    fn speeds_order_matches_hardware() {
        let t = DeviceTopology::new(vec![
            DeviceSpec::v100(),
            DeviceSpec::t4(),
            DeviceSpec::k80(),
        ]);
        let s = t.class_speeds();
        assert!((s[0] - 1.0).abs() < 1e-12, "v100 is the reference");
        assert!(s[0] > s[1] && s[1] > s[2], "v100 > t4 > k80: {s:?}");
        assert!(t.speed_of_worker(1) > 0.4 && t.speed_of_worker(1) < 0.7);
    }

    #[test]
    fn homogeneous_has_one_class() {
        let t = DeviceTopology::homogeneous(4, DeviceSpec::t4());
        assert_eq!(t.len(), 4);
        assert_eq!(t.classes().len(), 1);
        for w in 0..4 {
            assert_eq!(t.class_of(w), 0);
        }
    }
}
