//! Load-driven rebalancing: replicate hot groups onto cooler devices,
//! migrate cold groups off overloaded ones.
//!
//! The rebalancer folds per-launch observations (group, worker, measured
//! duration) into fixed windows. When a window closes with the busiest
//! device's utilization both high in absolute terms and skewed against the
//! coolest device, it acts — at most
//! [`RebalanceConfig::max_moves_per_window`] placement changes per window:
//!
//! * **Replicate** the hottest group of the hot device onto the coolest
//!   device (idempotent: a fully replicated group never fires again), so
//!   its launches split across both timelines;
//! * **Migrate** a cold group off the hot device, but only when the move
//!   *strictly lowers the peak utilization* — the classic load-balancing
//!   potential argument that rules out A→B→A ping-pong under stationary
//!   load.
//!
//! Both actions preserve the placement-table totality invariant by
//! construction: replication only adds replicas, and migration adds the
//! destination replica before dropping the source (which
//! [`PlacementTable::remove_replica`] refuses for a last replica anyway).

use std::collections::HashMap;

use crate::placement::placer::PlacementTable;
use crate::placement::topology::DeviceTopology;

/// Rebalancing policy knobs.
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// Observation window, µs.
    pub window_us: f64,
    /// Act when hot-device utilization exceeds `skew_ratio ×` the coolest
    /// device's.
    pub skew_ratio: f64,
    /// Max placement changes (replications + migrations) per window.
    pub max_moves_per_window: u32,
    /// Hot-device utilization floor below which no window acts (an idle
    /// fleet is skewed by noise, not by load).
    pub min_hot_util: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            window_us: 50_000.0,
            skew_ratio: 2.0,
            max_moves_per_window: 2,
            min_hot_util: 0.5,
        }
    }
}

/// One placement change decided at a window boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceAction {
    /// Group gained a replica on `to`.
    Replicate {
        /// Replicated group.
        group: u64,
        /// Destination worker.
        to: usize,
    },
    /// Group moved from `from` to `to` (destination replica added first).
    Migrate {
        /// Migrated group.
        group: u64,
        /// Source worker.
        from: usize,
        /// Destination worker.
        to: usize,
    },
}

/// Aggregate rebalancing statistics.
#[derive(Debug, Clone, Default)]
pub struct RebalanceStats {
    /// Windows evaluated.
    pub windows: u64,
    /// Replications applied.
    pub replications: u64,
    /// Migrations applied.
    pub migrations: u64,
}

impl RebalanceStats {
    /// Total placement changes.
    pub fn moves(&self) -> u64 {
        self.replications + self.migrations
    }
}

/// The windowed load rebalancer.
#[derive(Debug, Clone)]
pub struct Rebalancer {
    /// Policy knobs.
    pub cfg: RebalanceConfig,
    /// Aggregate stats.
    pub stats: RebalanceStats,
    window_start_us: f64,
    /// Busy µs per worker this window.
    device_busy: Vec<f64>,
    /// Busy µs per (group, worker) this window.
    group_busy: HashMap<(u64, usize), f64>,
}

impl Rebalancer {
    /// New rebalancer over `workers` pool workers.
    pub fn new(cfg: RebalanceConfig, workers: usize) -> Self {
        Rebalancer {
            cfg,
            stats: RebalanceStats::default(),
            window_start_us: 0.0,
            device_busy: vec![0.0; workers.max(1)],
            group_busy: HashMap::new(),
        }
    }

    /// Fold in one finished launch.
    pub fn observe_launch(&mut self, group: u64, worker: usize, duration_us: f64) {
        let w = worker % self.device_busy.len();
        self.device_busy[w] += duration_us;
        *self.group_busy.entry((group, w)).or_insert(0.0) += duration_us;
    }

    /// Close the window if due and apply at most `max_moves_per_window`
    /// placement changes. Call with the current clock from the drive loop;
    /// cheap no-op while the window is still open.
    pub fn maybe_rebalance(
        &mut self,
        now_us: f64,
        table: &mut PlacementTable,
        topo: &DeviceTopology,
    ) -> Vec<RebalanceAction> {
        if now_us < self.window_start_us + self.cfg.window_us {
            return Vec::new();
        }
        let span = (now_us - self.window_start_us).max(1e-9);
        self.stats.windows += 1;
        let util: Vec<f64> = self.device_busy.iter().map(|b| b / span).collect();
        let mut hot = 0usize;
        let mut cool = 0usize;
        for (w, u) in util.iter().enumerate() {
            if *u > util[hot] {
                hot = w;
            }
            if *u < util[cool] {
                cool = w;
            }
        }
        let mut actions = Vec::new();
        let skewed = hot != cool
            && util[hot] >= self.cfg.min_hot_util
            && util[hot] > self.cfg.skew_ratio * util[cool].max(1e-9);
        if skewed {
            let max_moves = self.cfg.max_moves_per_window as usize;
            // 1) replicate the hot device's hottest group onto the coolest
            let hottest = self.hottest_group_on(hot);
            if let Some(g) = hottest {
                if actions.len() < max_moves && table.add_replica(g, cool) {
                    self.stats.replications += 1;
                    actions.push(RebalanceAction::Replicate { group: g, to: cool });
                }
            }
            // 2) migrate the coldest co-resident group, strict-improvement
            // gated: the post-move peak must drop, or we skip (no ping-pong)
            if actions.len() < max_moves {
                // coldest group with OBSERVED load: a zero-busy group can
                // never pass the strict-improvement gate (moving it changes
                // nothing), and picking one would block the migration of a
                // real candidate behind it forever
                let candidate = table
                    .groups_on(hot)
                    .into_iter()
                    .filter(|g| Some(*g) != hottest && self.busy_of(*g, hot) > 0.0)
                    .min_by(|a, b| {
                        let ba = self.busy_of(*a, hot);
                        let bb = self.busy_of(*b, hot);
                        ba.partial_cmp(&bb).expect("NaN busy").then(a.cmp(b))
                    });
                if let Some(g) = candidate {
                    let moved = self.busy_of(g, hot) / span;
                    let speed_ratio = topo.speed_of_worker(hot)
                        / topo.speed_of_worker(cool).max(1e-9);
                    let hot_after = util[hot] - moved;
                    let cool_after = util[cool] + moved * speed_ratio;
                    if hot_after.max(cool_after) < util[hot].max(util[cool]) - 1e-9 {
                        table.add_replica(g, cool);
                        if table.remove_replica(g, hot) {
                            self.stats.migrations += 1;
                            actions.push(RebalanceAction::Migrate {
                                group: g,
                                from: hot,
                                to: cool,
                            });
                        }
                    }
                }
            }
        }
        self.window_start_us = now_us;
        for b in &mut self.device_busy {
            *b = 0.0;
        }
        self.group_busy.clear();
        actions
    }

    fn busy_of(&self, group: u64, worker: usize) -> f64 {
        self.group_busy
            .get(&(group, worker))
            .copied()
            .unwrap_or(0.0)
    }

    fn hottest_group_on(&self, worker: usize) -> Option<u64> {
        self.group_busy
            .iter()
            .filter(|((_, w), busy)| *w == worker && **busy > 0.0)
            .max_by(|(ka, a), (kb, b)| {
                a.partial_cmp(b)
                    .expect("NaN busy")
                    .then(kb.0.cmp(&ka.0))
            })
            .map(|((g, _), _)| *g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::DeviceSpec;
    use crate::placement::placer::Placer;

    fn topo_het() -> DeviceTopology {
        DeviceTopology::new(vec![DeviceSpec::v100(), DeviceSpec::t4()])
    }

    fn table_of(pairs: &[(u64, usize)]) -> PlacementTable {
        let mut t = PlacementTable::default();
        for (g, w) in pairs {
            t.add_replica(*g, *w);
        }
        t
    }

    #[test]
    fn hot_group_replicates_under_skew() {
        let topo = topo_het();
        let mut table = table_of(&[(0, 0), (1, 1)]);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        rb.observe_launch(0, 0, 45_000.0); // group 0 saturates worker 0
        rb.observe_launch(1, 1, 2_000.0);
        let actions = rb.maybe_rebalance(50_000.0, &mut table, &topo);
        assert_eq!(
            actions,
            vec![RebalanceAction::Replicate { group: 0, to: 1 }]
        );
        assert_eq!(table.replicas_of(0), &[0, 1]);
        assert_eq!(rb.stats.replications, 1);
        assert!(table.is_total(2, 2));
    }

    #[test]
    fn no_action_while_window_open_or_fleet_idle() {
        let topo = topo_het();
        let mut table = table_of(&[(0, 0), (1, 1)]);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        rb.observe_launch(0, 0, 45_000.0);
        assert!(rb.maybe_rebalance(10_000.0, &mut table, &topo).is_empty());
        // window closes but the fleet is idle: 10% hot util is noise
        let mut rb2 = Rebalancer::new(RebalanceConfig::default(), 2);
        rb2.observe_launch(0, 0, 5_000.0);
        assert!(rb2.maybe_rebalance(50_000.0, &mut table, &topo).is_empty());
        assert_eq!(rb2.stats.windows, 1, "the window was still evaluated");
    }

    #[test]
    fn cold_group_migrates_only_on_strict_improvement() {
        let topo = DeviceTopology::homogeneous(2, DeviceSpec::v100());
        let mut table = table_of(&[(0, 0), (1, 0)]);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        rb.observe_launch(0, 0, 30_000.0);
        rb.observe_launch(1, 0, 12_000.0);
        let actions = rb.maybe_rebalance(50_000.0, &mut table, &topo);
        assert!(actions.contains(&RebalanceAction::Replicate { group: 0, to: 1 }));
        assert!(actions.contains(&RebalanceAction::Migrate {
            group: 1,
            from: 0,
            to: 1
        }));
        assert_eq!(table.replicas_of(1), &[1], "group 1 left worker 0");
        assert!(table.is_total(2, 2));
        // a dominating single group must NOT migrate (the swap would just
        // relabel the hot device) — replication is the only action
        let mut table2 = table_of(&[(0, 0), (1, 1)]);
        let mut rb2 = Rebalancer::new(RebalanceConfig::default(), 2);
        rb2.observe_launch(0, 0, 45_000.0);
        let actions2 = rb2.maybe_rebalance(50_000.0, &mut table2, &topo);
        assert_eq!(
            actions2,
            vec![RebalanceAction::Replicate { group: 0, to: 1 }]
        );
        assert_eq!(rb2.stats.migrations, 0);
    }

    #[test]
    fn idle_group_never_blocks_a_real_migration_candidate() {
        // worker 0 hosts hot A (g0), idle B (g1, zero launches) and
        // moderate C (g2): the migration candidate must be C — picking
        // idle B (busy 0, no possible improvement) would block C forever
        let topo = DeviceTopology::homogeneous(2, DeviceSpec::v100());
        let mut table = table_of(&[(0, 0), (1, 0), (2, 0)]);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        rb.observe_launch(0, 0, 30_000.0);
        rb.observe_launch(2, 0, 7_500.0);
        let actions = rb.maybe_rebalance(50_000.0, &mut table, &topo);
        assert!(actions.contains(&RebalanceAction::Migrate {
            group: 2,
            from: 0,
            to: 1
        }));
        assert_eq!(table.replicas_of(2), &[1]);
        assert_eq!(table.replicas_of(1), &[0], "idle group stays put");
        assert!(table.is_total(3, 2));
    }

    #[test]
    fn moves_bounded_per_window() {
        let topo = DeviceTopology::homogeneous(2, DeviceSpec::v100());
        let mut table = table_of(&[(0, 0), (1, 0)]);
        let cfg = RebalanceConfig {
            max_moves_per_window: 1,
            ..RebalanceConfig::default()
        };
        let mut rb = Rebalancer::new(cfg, 2);
        rb.observe_launch(0, 0, 30_000.0);
        rb.observe_launch(1, 0, 12_000.0);
        let actions = rb.maybe_rebalance(50_000.0, &mut table, &topo);
        assert_eq!(actions.len(), 1, "cap binds");
        assert_eq!(rb.stats.moves(), 1);
    }

    #[test]
    fn replication_is_idempotent_across_windows() {
        let topo = topo_het();
        let mut table = table_of(&[(0, 0), (1, 1)]);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        let mut now = 0.0;
        for _ in 0..5 {
            rb.observe_launch(0, 0, 45_000.0);
            rb.observe_launch(1, 1, 1_000.0);
            now += 50_000.0;
            rb.maybe_rebalance(now, &mut table, &topo);
        }
        assert_eq!(
            rb.stats.replications, 1,
            "a fully replicated group never re-fires"
        );
        assert_eq!(table.replicas_of(0).len(), 2);
    }

    #[test]
    fn placed_then_rebalanced_stays_total() {
        let topo = topo_het();
        let costs: Vec<(u64, f64)> = (0..5).map(|g| (g, (g + 1) as f64 * 50.0)).collect();
        let mut table = Placer::place(&costs, &topo);
        let mut rb = Rebalancer::new(RebalanceConfig::default(), 2);
        let mut now = 0.0;
        for round in 0..10 {
            for g in 0..5u64 {
                let reps = table.replicas_of(g).to_vec();
                let total = if g == 0 { 40_000.0 } else { 1_500.0 };
                for w in &reps {
                    rb.observe_launch(g, *w, total / reps.len() as f64);
                }
            }
            now += 50_000.0;
            let actions = rb.maybe_rebalance(now, &mut table, &topo);
            assert!(actions.len() <= 2, "round {round}: bounded moves");
            assert!(table.is_total(5, 2), "round {round}: totality");
        }
    }
}
