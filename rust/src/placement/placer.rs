//! Initial placement: cost-aware LPT assignment of model groups onto
//! workers, plus the routing table the launch stage consults per launch.
//!
//! The table maps each coalescing group to its replica workers (primary
//! first). **Totality invariant:** every group holds ≥ 1 replica at all
//! times — [`PlacementTable::remove_replica`] refuses to drop the last one,
//! and [`PlacementTable::route`] falls back to hashing only for a group
//! that was never placed (defense in depth; pinned by the placement
//! property tests).

use std::collections::BTreeMap;

use crate::placement::topology::DeviceTopology;

/// Group → replica-worker routing table.
#[derive(Debug, Clone, Default)]
pub struct PlacementTable {
    replicas: BTreeMap<u64, Vec<usize>>,
}

impl PlacementTable {
    /// Replica workers of a group (primary first; empty = never placed).
    pub fn replicas_of(&self, group: u64) -> &[usize] {
        self.replicas.get(&group).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Primary (first-placed) worker of a group.
    pub fn primary_of(&self, group: u64) -> Option<usize> {
        self.replicas_of(group).first().copied()
    }

    /// Groups with at least one replica.
    pub fn groups(&self) -> impl Iterator<Item = u64> + '_ {
        self.replicas.keys().copied()
    }

    /// Groups replicated on a worker.
    pub fn groups_on(&self, worker: usize) -> Vec<u64> {
        self.replicas
            .iter()
            .filter(|(_, ws)| ws.contains(&worker))
            .map(|(g, _)| *g)
            .collect()
    }

    /// Add a replica (no-op if already present). Returns true if added.
    pub fn add_replica(&mut self, group: u64, worker: usize) -> bool {
        let ws = self.replicas.entry(group).or_default();
        if ws.contains(&worker) {
            false
        } else {
            ws.push(worker);
            true
        }
    }

    /// Drop a replica. Refuses to remove the last one (totality) or a
    /// worker the group is not on. Returns true if removed.
    pub fn remove_replica(&mut self, group: u64, worker: usize) -> bool {
        let Some(ws) = self.replicas.get_mut(&group) else {
            return false;
        };
        if ws.len() <= 1 {
            return false;
        }
        match ws.iter().position(|w| *w == worker) {
            Some(i) => {
                ws.remove(i);
                true
            }
            None => false,
        }
    }

    /// Route one launch: the least-loaded replica under the caller's load
    /// signal (`load[w]` = queue depth, busy-until time, ... — lower is
    /// freer), ties to the lowest worker id for determinism. A group that
    /// was never placed falls back to the legacy group-hash route so
    /// routing stays total even against a buggy placer.
    pub fn route(&self, group: u64, load: &[f64]) -> usize {
        let ws = self.replicas_of(group);
        if ws.is_empty() {
            return if load.is_empty() {
                0
            } else {
                group as usize % load.len()
            };
        }
        let mut best = ws[0];
        let mut best_load = load.get(best).copied().unwrap_or(0.0);
        for &w in &ws[1..] {
            let l = load.get(w).copied().unwrap_or(0.0);
            if l < best_load || (l == best_load && w < best) {
                best = w;
                best_load = l;
            }
        }
        best
    }

    /// True when every group in `0..groups` has ≥ 1 replica and every
    /// replica id addresses a live worker (< `workers`), with no duplicate
    /// replicas — the property the placement tests pin.
    pub fn is_total(&self, groups: u64, workers: usize) -> bool {
        (0..groups).all(|g| {
            let ws = self.replicas_of(g);
            !ws.is_empty()
                && ws.iter().all(|w| *w < workers)
                && ws.iter().enumerate().all(|(i, w)| !ws[..i].contains(w))
        })
    }
}

/// Greedy longest-processing-time placer.
#[derive(Debug, Clone, Default)]
pub struct Placer;

impl Placer {
    /// Place groups onto workers: heaviest estimated total work first, each
    /// onto the worker whose *normalized* finish time (accumulated work ÷
    /// device speed) stays lowest. Every group gets exactly one initial
    /// replica; the rebalancer grows hot groups later.
    pub fn place(costs: &[(u64, f64)], topo: &DeviceTopology) -> PlacementTable {
        let mut table = PlacementTable::default();
        if topo.is_empty() {
            return table;
        }
        let mut sorted: Vec<(u64, f64)> = costs.to_vec();
        sorted.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .expect("NaN placement cost")
                .then(a.0.cmp(&b.0))
        });
        let mut load = vec![0.0f64; topo.len()];
        for (group, cost) in sorted {
            let mut best = 0usize;
            let mut best_finish = f64::INFINITY;
            for (w, l) in load.iter().enumerate() {
                let finish = (*l + cost) / topo.speed_of_worker(w).max(1e-9);
                if finish < best_finish {
                    best = w;
                    best_finish = finish;
                }
            }
            load[best] += cost;
            table.add_replica(group, best);
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::DeviceSpec;

    fn topo2() -> DeviceTopology {
        DeviceTopology::new(vec![DeviceSpec::v100(), DeviceSpec::t4()])
    }

    #[test]
    fn place_is_total_and_balances() {
        let costs: Vec<(u64, f64)> = (0..6).map(|g| (g, 100.0 * (g + 1) as f64)).collect();
        let t = Placer::place(&costs, &topo2());
        assert!(t.is_total(6, 2));
        // both workers get work
        assert!(!t.groups_on(0).is_empty());
        assert!(!t.groups_on(1).is_empty());
        // the heaviest group lands on the fastest (empty) device first
        assert_eq!(t.primary_of(5), Some(0));
    }

    #[test]
    fn single_worker_takes_everything() {
        let topo = DeviceTopology::homogeneous(1, DeviceSpec::v100());
        let costs = vec![(0u64, 10.0), (1, 20.0)];
        let t = Placer::place(&costs, &topo);
        assert!(t.is_total(2, 1));
        assert_eq!(t.groups_on(0), vec![0, 1]);
    }

    #[test]
    fn route_picks_least_loaded_replica() {
        let mut t = PlacementTable::default();
        t.add_replica(3, 0);
        t.add_replica(3, 2);
        assert_eq!(t.route(3, &[5.0, 0.0, 1.0]), 2, "worker 1 is not a replica");
        assert_eq!(t.route(3, &[0.5, 0.0, 1.0]), 0);
        // tie goes to the lowest worker id
        assert_eq!(t.route(3, &[1.0, 9.0, 1.0]), 0);
        // unplaced group: legacy hash fallback stays in range
        assert_eq!(t.route(7, &[0.0, 0.0, 0.0]), 1);
    }

    #[test]
    fn remove_replica_refuses_last() {
        let mut t = PlacementTable::default();
        t.add_replica(0, 1);
        assert!(!t.remove_replica(0, 1), "last replica is pinned");
        t.add_replica(0, 2);
        assert!(t.remove_replica(0, 1));
        assert_eq!(t.replicas_of(0), &[2]);
        assert!(!t.remove_replica(0, 5), "not a replica");
    }

    #[test]
    fn add_replica_idempotent() {
        let mut t = PlacementTable::default();
        assert!(t.add_replica(0, 1));
        assert!(!t.add_replica(0, 1));
        assert_eq!(t.replicas_of(0).len(), 1);
    }
}
