//! The model zoo: 12 networks spanning 2012–2018, with release year and
//! per-layer shapes — the source data for Fig. 2 (latency over model
//! generations) and Fig. 7 (GEMM shape clustering).
//!
//! Layer tables follow the original papers (AlexNet [29], VGG [38],
//! ResNet [22], DenseNet [25], SENet [24]); very deep models use stage
//! replication exactly as published. Aggregate FLOPs are asserted against
//! the commonly cited numbers in tests.

use crate::gpu::kernel::KernelDesc;
use crate::model::layers::LayerDesc;

/// A zoo model: name, release year, layer chain.
#[derive(Debug, Clone)]
pub struct Model {
    /// Canonical name ("resnet50", ...).
    pub name: &'static str,
    /// Publication year (Fig. 2 x-axis).
    pub year: u32,
    /// Layers in program order.
    pub layers: Vec<LayerDesc>,
}

impl Model {
    /// All layer GEMMs at batch `b`, in program order.
    pub fn gemms(&self, b: u32) -> Vec<KernelDesc> {
        self.layers.iter().flat_map(|l| l.gemms(b)).collect()
    }

    /// Total FLOPs per query at batch 1.
    pub fn flops(&self) -> f64 {
        self.layers.iter().map(|l| l.flops(1)).sum()
    }

    /// Number of scheduled kernels at batch 1.
    pub fn kernel_count(&self) -> usize {
        self.gemms(1).len()
    }
}

fn conv(out_hw: u32, in_ch: u32, out_ch: u32, ksize: u32) -> LayerDesc {
    LayerDesc::Conv {
        out_hw,
        in_ch,
        out_ch,
        ksize,
    }
}

fn fc(d_in: u32, d_out: u32) -> LayerDesc {
    LayerDesc::Fc { d_in, d_out }
}

fn alexnet() -> Model {
    Model {
        name: "alexnet",
        year: 2012,
        layers: vec![
            conv(55, 3, 96, 11),
            // convs 2, 4, 5 are 2-way grouped in the original (half in_ch)
            conv(27, 48, 256, 5),
            conv(13, 256, 384, 3),
            conv(13, 192, 384, 3),
            conv(13, 192, 256, 3),
            fc(9216, 4096),
            fc(4096, 4096),
            fc(4096, 1000),
        ],
    }
}

fn vgg16() -> Model {
    let mut layers = Vec::new();
    // (repeat, out_hw, in_ch, out_ch)
    for &(rep, hw, ic, oc) in &[
        (1, 224, 3, 64),
        (1, 224, 64, 64),
        (1, 112, 64, 128),
        (1, 112, 128, 128),
        (1, 56, 128, 256),
        (2, 56, 256, 256),
        (1, 28, 256, 512),
        (2, 28, 512, 512),
        (1, 14, 512, 512),
        (2, 14, 512, 512),
    ] {
        for _ in 0..rep {
            layers.push(conv(hw, ic, oc, 3));
        }
    }
    layers.push(fc(25088, 4096));
    layers.push(fc(4096, 4096));
    layers.push(fc(4096, 1000));
    Model {
        name: "vgg16",
        year: 2014,
        layers,
    }
}

fn inception_v3() -> Model {
    // representative trunk + mixed blocks (shape-faithful, stage-replicated)
    let mut layers = vec![
        conv(149, 3, 32, 3),
        conv(147, 32, 32, 3),
        conv(147, 32, 64, 3),
        conv(73, 64, 80, 1),
        conv(71, 80, 192, 3),
    ];
    for _ in 0..3 {
        layers.push(conv(35, 192, 64, 1));
        layers.push(conv(35, 64, 96, 3));
        layers.push(conv(35, 48, 64, 5));
    }
    for _ in 0..4 {
        layers.push(conv(17, 768, 192, 1));
        layers.push(conv(17, 128, 192, 7)); // 1x7/7x1 factorized pair (as one)
    }
    for _ in 0..2 {
        layers.push(conv(8, 1280, 320, 1));
        layers.push(conv(8, 384, 384, 3));
    }
    layers.push(fc(2048, 1000));
    Model {
        name: "inception_v3",
        year: 2015,
        layers,
    }
}

fn resnet_basic(name: &'static str, year: u32, blocks: [u32; 4]) -> Model {
    // basic blocks (two 3x3 convs), ResNet-18/34 style
    let mut layers = vec![conv(112, 3, 64, 7)];
    let stages = [(56u32, 64u32), (28, 128), (14, 256), (7, 512)];
    for (si, &(hw, ch)) in stages.iter().enumerate() {
        for b in 0..blocks[si] {
            let in_ch = if b == 0 && si > 0 { ch / 2 } else { ch };
            layers.push(conv(hw, in_ch, ch, 3));
            layers.push(conv(hw, ch, ch, 3));
        }
    }
    layers.push(fc(512, 1000));
    Model { name, year, layers }
}

fn resnet_bottleneck(name: &'static str, year: u32, blocks: [u32; 4]) -> Model {
    // bottleneck blocks (1x1 -> 3x3 -> 1x1), ResNet-50/101/152 style
    let mut layers = vec![conv(112, 3, 64, 7)];
    let stages = [(56u32, 64u32), (28, 128), (14, 256), (7, 512)];
    for (si, &(hw, ch)) in stages.iter().enumerate() {
        let expanded = ch * 4;
        for b in 0..blocks[si] {
            let in_ch = if b == 0 {
                if si == 0 {
                    64
                } else {
                    ch * 2
                }
            } else {
                expanded
            };
            layers.push(conv(hw, in_ch, ch, 1));
            layers.push(conv(hw, ch, ch, 3));
            layers.push(conv(hw, ch, expanded, 1));
        }
    }
    layers.push(fc(2048, 1000));
    Model { name, year, layers }
}

fn densenet121() -> Model {
    // dense blocks with growth 32; each layer: 1x1 (4g) + 3x3 (g)
    let mut layers = vec![conv(112, 3, 64, 7)];
    let cfg = [(56u32, 6u32, 64u32), (28, 12, 128), (14, 24, 256), (7, 16, 512)];
    for &(hw, n, ch0) in &cfg {
        let mut ch = ch0;
        for _ in 0..n {
            layers.push(conv(hw, ch, 128, 1));
            layers.push(conv(hw, 128, 32, 3));
            ch += 32;
        }
    }
    layers.push(fc(1024, 1000));
    Model {
        name: "densenet121",
        year: 2016,
        layers,
    }
}

fn senet(name: &'static str, year: u32, blocks: [u32; 4], width: u32) -> Model {
    // SE-ResNeXt-style: bottlenecks + SE gating FCs per block
    let mut m = resnet_bottleneck("tmp", year, blocks);
    let mut layers = Vec::new();
    let stages = [(56u32, 64u32), (28, 128), (14, 256), (7, 512)];
    let mut block_idx = 0usize;
    layers.push(m.layers.remove(0));
    for (si, &(_hw, ch)) in stages.iter().enumerate() {
        for _ in 0..blocks[si] {
            for _ in 0..3 {
                layers.push(m.layers.remove(0));
            }
            // SE: squeeze FC pair on the expanded channels
            let c = ch * 4 * width / 64;
            layers.push(fc(c, c / 16));
            layers.push(fc(c / 16, c));
            block_idx += 1;
        }
    }
    let _ = block_idx;
    layers.push(fc(2048, 1000));
    Model { name, year, layers }
}

fn mobilenet_v1() -> Model {
    let mut layers = vec![conv(112, 3, 32, 3)];
    for &(hw, ch, oc) in &[
        (112u32, 32u32, 64u32),
        (56, 64, 128),
        (56, 128, 128),
        (28, 128, 256),
        (28, 256, 256),
        (14, 256, 512),
        (14, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
        (7, 512, 1024),
        (7, 1024, 1024),
    ] {
        layers.push(LayerDesc::DwConv { out_hw: hw, ch, out_ch: oc });
    }
    layers.push(fc(1024, 1000));
    Model {
        name: "mobilenet_v1",
        year: 2017,
        layers,
    }
}

fn lstm_2x1024() -> Model {
    Model {
        name: "lstm_2x1024",
        year: 2015,
        layers: vec![
            LayerDesc::Lstm {
                d_in: 512,
                hidden: 1024,
                steps: 50,
            },
            LayerDesc::Lstm {
                d_in: 1024,
                hidden: 1024,
                steps: 50,
            },
            fc(1024, 32000),
        ],
    }
}

fn gru_512() -> Model {
    Model {
        name: "gru_512",
        year: 2016,
        layers: vec![
            LayerDesc::Lstm {
                d_in: 256,
                hidden: 512,
                steps: 30,
            },
            fc(512, 10000),
        ],
    }
}

fn bert_base() -> Model {
    Model {
        name: "bert_base",
        year: 2018,
        layers: (0..12)
            .map(|_| LayerDesc::Attention { seq: 128, d: 768 })
            .chain(std::iter::once(fc(768, 2)))
            .collect(),
    }
}

fn transformer_small() -> Model {
    Model {
        name: "transformer_small",
        year: 2017,
        layers: (0..6)
            .map(|_| LayerDesc::Attention { seq: 64, d: 512 })
            .chain(std::iter::once(fc(512, 32000)))
            .collect(),
    }
}

/// The full zoo, ordered by release year.
pub fn zoo() -> Vec<Model> {
    vec![
        alexnet(),
        vgg16(),
        inception_v3(),
        resnet_basic("resnet18", 2015, [2, 2, 2, 2]),
        resnet_bottleneck("resnet50", 2015, [3, 4, 6, 3]),
        lstm_2x1024(),
        densenet121(),
        gru_512(),
        mobilenet_v1(),
        transformer_small(),
        senet("senet154", 2017, [3, 8, 36, 3], 64),
        bert_base(),
    ]
}

/// Look a model up by name.
pub fn by_name(name: &str) -> Option<Model> {
    zoo().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_twelve_models_sorted_by_year() {
        let z = zoo();
        assert_eq!(z.len(), 12);
        let years: Vec<u32> = z.iter().map(|m| m.year).collect();
        let mut sorted = years.clone();
        sorted.sort_unstable();
        assert_eq!(years, sorted);
    }

    #[test]
    fn flops_match_literature() {
        // commonly cited per-image FLOPs (2·MACs), generous tolerance since
        // we count GEMM work only:
        let checks = [
            ("alexnet", 1.4e9, 0.5),      // ~1.4 GFLOP
            ("vgg16", 31.0e9, 0.3),       // ~31 GFLOP
            ("resnet18", 3.6e9, 0.4),     // ~3.6 GFLOP
            ("resnet50", 7.7e9, 0.4),     // ~8 GFLOP (2*MACs)
            ("densenet121", 5.7e9, 0.5),  // ~5.7 GFLOP
            ("mobilenet_v1", 1.1e9, 0.5), // ~1.1 GFLOP
            ("bert_base", 22.0e9, 0.5),   // ~22 GFLOP @ seq128 (GEMM part)
        ];
        for (name, expect, tol) in checks {
            let m = by_name(name).unwrap();
            let f = m.flops();
            assert!(
                (f - expect).abs() / expect < tol,
                "{name}: {:.2e} vs expected {:.2e}",
                f,
                expect
            );
        }
    }

    #[test]
    fn senet_is_heaviest_conv_net() {
        let z = zoo();
        let se = z.iter().find(|m| m.name == "senet154").unwrap();
        let rn = z.iter().find(|m| m.name == "resnet50").unwrap();
        assert!(se.flops() > 2.0 * rn.flops());
        assert!(se.kernel_count() > 150);
    }

    #[test]
    fn models_grow_over_time() {
        // Fig. 2's premise: newer CNNs are heavier than AlexNet
        let a = by_name("alexnet").unwrap().flops();
        let s = by_name("senet154").unwrap().flops();
        assert!(s > 10.0 * a);
    }

    #[test]
    fn gemm_extraction_batch_scaling() {
        let m = by_name("resnet50").unwrap();
        let g1 = m.gemms(1);
        let g8 = m.gemms(8);
        assert_eq!(g1.len(), g8.len());
        for (a, b) in g1.iter().zip(&g8) {
            assert_eq!(b.m, 8 * a.m);
            assert_eq!((b.k, b.n), (a.k, a.n));
        }
    }

    #[test]
    fn resnet18_contains_conv2_2_shape() {
        // the Fig. 6 kernel must exist in the zoo extraction
        let m = by_name("resnet18").unwrap();
        assert!(m
            .gemms(1)
            .iter()
            .any(|k| k.m == 3136 && k.k == 576 && k.n == 64));
    }

    #[test]
    fn lookup_unknown_is_none() {
        assert!(by_name("resnet9000").is_none());
    }
}
