//! Layer descriptors and their GEMM lowering (im2col et al.).

use crate::gpu::kernel::KernelDesc;

/// A neural-network layer, described at the granularity the JIT schedules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerDesc {
    /// 2-D convolution: output spatial `out_hw × out_hw`, `in_ch → out_ch`,
    /// square kernel `ksize`. Lowers to GEMM via im2col:
    /// `M = b·out_hw², K = in_ch·ksize², N = out_ch`.
    Conv {
        /// Output spatial side.
        out_hw: u32,
        /// Input channels.
        in_ch: u32,
        /// Output channels.
        out_ch: u32,
        /// Kernel side (1, 3, 5, 7, 11...).
        ksize: u32,
    },
    /// Depthwise separable conv (MobileNet): modeled as the pointwise GEMM
    /// (the depthwise part is bandwidth-bound and tiny in FLOPs).
    DwConv {
        /// Output spatial side.
        out_hw: u32,
        /// Channels.
        ch: u32,
        /// Pointwise expansion output channels.
        out_ch: u32,
    },
    /// Fully-connected: `M = b, K = d_in, N = d_out`.
    Fc {
        /// Input features.
        d_in: u32,
        /// Output features.
        d_out: u32,
    },
    /// LSTM cell step: gates = [x;h]·W with `K = d_in + hidden`,
    /// `N = 4·hidden`, repeated `steps` times (sequence length).
    Lstm {
        /// Input features.
        d_in: u32,
        /// Hidden size.
        hidden: u32,
        /// Unrolled time steps.
        steps: u32,
    },
    /// Transformer encoder block at sequence length `seq`, width `d`:
    /// QKV + attention-out + 2 MLP GEMMs (`d → 4d → d`).
    Attention {
        /// Sequence length (folded into M).
        seq: u32,
        /// Model width.
        d: u32,
    },
}

impl LayerDesc {
    /// Lower this layer at batch `b` into its GEMM kernel sequence.
    pub fn gemms(&self, b: u32) -> Vec<KernelDesc> {
        match *self {
            LayerDesc::Conv {
                out_hw,
                in_ch,
                out_ch,
                ksize,
            } => vec![KernelDesc::gemm(b * out_hw * out_hw, in_ch * ksize * ksize, out_ch)],
            LayerDesc::DwConv { out_hw, ch, out_ch } => {
                vec![KernelDesc::gemm(b * out_hw * out_hw, ch, out_ch)]
            }
            LayerDesc::Fc { d_in, d_out } => vec![KernelDesc::gemm(b, d_in, d_out)],
            LayerDesc::Lstm {
                d_in,
                hidden,
                steps,
            } => (0..steps)
                .map(|_| KernelDesc::gemm(b, d_in + hidden, 4 * hidden))
                .collect(),
            LayerDesc::Attention { seq, d } => vec![
                KernelDesc::gemm(b * seq, d, 3 * d), // QKV
                KernelDesc::gemm(b * seq, d, d),     // attn out
                KernelDesc::gemm(b * seq, d, 4 * d), // MLP up
                KernelDesc::gemm(b * seq, 4 * d, d), // MLP down
            ],
        }
    }

    /// FLOPs at batch `b`.
    pub fn flops(&self, b: u32) -> f64 {
        self.gemms(b).iter().map(|k| k.flops()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_im2col_shape() {
        // ResNet-18 conv2_2: 56x56 spatial, 64->64 ch, 3x3
        let l = LayerDesc::Conv {
            out_hw: 56,
            in_ch: 64,
            out_ch: 64,
            ksize: 3,
        };
        let g = &l.gemms(1)[0];
        assert_eq!((g.m, g.k, g.n), (3136, 576, 64));
        // batch scales M only
        let g8 = &l.gemms(8)[0];
        assert_eq!((g8.m, g8.k, g8.n), (8 * 3136, 576, 64));
    }

    #[test]
    fn fc_shape() {
        let l = LayerDesc::Fc {
            d_in: 4096,
            d_out: 1000,
        };
        let g = &l.gemms(4)[0];
        assert_eq!((g.m, g.k, g.n), (4, 4096, 1000));
    }

    #[test]
    fn lstm_unrolls_steps() {
        let l = LayerDesc::Lstm {
            d_in: 512,
            hidden: 1024,
            steps: 20,
        };
        let gs = l.gemms(1);
        assert_eq!(gs.len(), 20);
        assert_eq!((gs[0].m, gs[0].k, gs[0].n), (1, 1536, 4096));
    }

    #[test]
    fn attention_block_gemms() {
        let l = LayerDesc::Attention { seq: 128, d: 768 };
        let gs = l.gemms(1);
        assert_eq!(gs.len(), 4);
        assert_eq!((gs[0].m, gs[0].k, gs[0].n), (128, 768, 2304));
        // BERT-base block ≈ 2 * 12 * seq * d^2 flops-ish; sanity: positive
        assert!(l.flops(1) > 1e8);
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let l = LayerDesc::Conv {
            out_hw: 28,
            in_ch: 128,
            out_ch: 128,
            ksize: 3,
        };
        assert!((l.flops(4) - 4.0 * l.flops(1)).abs() < 1.0);
    }
}
