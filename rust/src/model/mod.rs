//! DNN model zoo: per-layer GEMM shape extraction.
//!
//! Fig. 2 (latency-over-model-generations) and Fig. 7 (GEMM shape
//! clustering) are functions of *architectural facts* — layer shapes —
//! which this module reproduces exactly from the papers describing each
//! network. Convolutions become GEMMs by im2col, recurrent cells by gate
//! stacking, attention by QKV projection — matching how cuDNN/cuBLAS (and
//! our Pallas superkernel) actually execute them.

pub mod layers;
pub mod zoo;

pub use layers::LayerDesc;
pub use zoo::{zoo, Model};
