//! Device specifications for the simulator.
//!
//! Numbers come from vendor datasheets (peak FLOPS, bandwidth, SM counts);
//! behavioural constants (context-switch flush, launch overhead) are set to
//! reproduce the *shapes* in the paper's §3/§4 measurements and are
//! documented per-field. The op:byte ratios quoted in §3 (K80 18 → V100 139,
//! TPUv2 300, Inferentia ~500) fall out of these specs — asserted in tests.

/// A simulated accelerator (or CPU) device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Human-readable name ("v100", ...).
    pub name: &'static str,
    /// Streaming multiprocessors (or core complexes for CPU).
    pub sms: u32,
    /// Max resident blocks per SM (occupancy ceiling).
    pub blocks_per_sm: u32,
    /// Peak dense fp32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Fixed kernel launch overhead, µs.
    pub launch_us: f64,
    /// Context switch cost between *processes* (pipeline flush), µs.
    /// §4.1: "context switching overhead is high because GPUs need to flush
    /// the execution pipeline".
    pub ctx_switch_us: f64,
    /// Fraction of peak a well-shaped DNN GEMM kernel can sustain once the
    /// device is spatially full (instruction mix, im2col traffic, wave
    /// tails, framework overhead). Calibrated to Fig. 3's observation that
    /// large-batch ResNet-50 "struggles to achieve 40%" of V100 peak.
    pub max_eff: f64,
    /// Per-layer dispatch overhead on the host side, µs (framework cost;
    /// dominates small layers on CPU — part of why Fig. 2 CPU latencies
    /// blow past the 300 ms SLO).
    pub layer_overhead_us: f64,
}

impl DeviceSpec {
    /// NVIDIA V100 (SXM2): 80 SMs, 15.7 TFLOPS fp32, 900 GB/s HBM2.
    pub fn v100() -> Self {
        DeviceSpec {
            name: "v100",
            sms: 80,
            blocks_per_sm: 32,
            peak_flops: 15.7e12,
            mem_bw: 900e9,
            launch_us: 5.0,
            ctx_switch_us: 200.0,
            max_eff: 0.55,
            layer_overhead_us: 6.0,
        }
    }

    /// NVIDIA T4: 40 SMs, 8.1 TFLOPS fp32, 320 GB/s.
    pub fn t4() -> Self {
        DeviceSpec {
            name: "t4",
            sms: 40,
            blocks_per_sm: 32,
            peak_flops: 8.1e12,
            mem_bw: 320e9,
            launch_us: 5.0,
            ctx_switch_us: 200.0,
            max_eff: 0.55,
            layer_overhead_us: 6.0,
        }
    }

    /// NVIDIA K80 (per GK210 die): 13 SMs, ~4.37 TFLOPS fp32, 240 GB/s.
    /// §3 quotes op:byte = 18 for the K80.
    pub fn k80() -> Self {
        DeviceSpec {
            name: "k80",
            sms: 13,
            blocks_per_sm: 16,
            peak_flops: 4.37e12,
            mem_bw: 240e9,
            launch_us: 8.0,
            ctx_switch_us: 250.0,
            max_eff: 0.50,
            layer_overhead_us: 8.0,
        }
    }

    /// TPU-v2-like: one big MXU "SM"; 45 TFLOPS, 150 GB/s more-or-less
    /// (op:byte = 300 per §3).
    pub fn tpuv2() -> Self {
        DeviceSpec {
            name: "tpuv2",
            sms: 2,
            blocks_per_sm: 4,
            peak_flops: 45e12,
            mem_bw: 150e9,
            launch_us: 10.0,
            ctx_switch_us: 200.0,
            max_eff: 0.9,
            layer_overhead_us: 10.0,
        }
    }

    /// Xeon-class CPU running a 2019 inference framework. Effective GEMM
    /// throughput calibrated so Fig. 2 reproduces: ResNet-50 ≈ 0.2 s,
    /// SENet-class models > 2 s (paper: SENet-184 = 4.1 s).
    pub fn cpu_xeon() -> Self {
        DeviceSpec {
            name: "cpu-xeon",
            sms: 16,
            blocks_per_sm: 1,
            peak_flops: 1.5e12,
            mem_bw: 80e9,
            launch_us: 0.0,
            ctx_switch_us: 5.0,
            // inference frameworks at batch 1 reach only a few % of peak on
            // CPU (strided convs, no fused epilogues, frequency throttling)
            max_eff: 0.025,
            layer_overhead_us: 1500.0,
        }
    }

    /// Device op:byte ratio (FLOP per byte at the roofline knee).
    pub fn op_byte_ratio(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Total resident-block capacity.
    pub fn block_capacity(&self) -> u64 {
        self.sms as u64 * self.blocks_per_sm as u64
    }

    /// Every name [`DeviceSpec::by_name`] accepts.
    pub const NAMES: [&'static str; 6] = ["v100", "t4", "k80", "tpuv2", "cpu", "cpu-xeon"];

    /// Look a device up by name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "v100" => Some(Self::v100()),
            "t4" => Some(Self::t4()),
            "k80" => Some(Self::k80()),
            "tpuv2" => Some(Self::tpuv2()),
            "cpu" | "cpu-xeon" => Some(Self::cpu_xeon()),
            _ => None,
        }
    }

    /// Parse a CLI device name. Unlike [`DeviceSpec::by_name`]'s silent
    /// `None`, a bad name is a hard error that names the offender and
    /// lists every valid spec — a typo'd `--devices` must never fall back
    /// to a default device.
    pub fn parse(name: &str) -> crate::Result<Self> {
        Self::by_name(name).ok_or_else(|| {
            crate::Error::config(format!(
                "unknown device '{name}' (valid: {})",
                Self::NAMES.join(", ")
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_byte_ratios_match_paper_section3() {
        // §3: "op to byte ratios have risen from 18 with the K80 to 139 for
        // the V100"; TPUv2 = 300.
        assert!((DeviceSpec::k80().op_byte_ratio() - 18.2).abs() < 1.0);
        assert!((DeviceSpec::v100().op_byte_ratio() - 17.4).abs() < 0.5); // fp32
        // NOTE: the paper's 139 counts *tensor-core* FLOPs (125 TF fp16);
        // at fp32 the V100 knee is 17.4. The trend (K80 -> V100 -> TPU)
        // still holds at fixed precision:
        assert!(DeviceSpec::tpuv2().op_byte_ratio() > 250.0);
        assert!(
            DeviceSpec::tpuv2().op_byte_ratio() > DeviceSpec::k80().op_byte_ratio()
        );
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceSpec::by_name("v100").unwrap().sms, 80);
        assert_eq!(DeviceSpec::by_name("cpu").unwrap().name, "cpu-xeon");
        assert!(DeviceSpec::by_name("h100").is_none());
    }

    #[test]
    fn parse_reports_bad_name_and_valid_specs() {
        assert_eq!(DeviceSpec::parse("t4").unwrap().sms, 40);
        let err = DeviceSpec::parse("h100").unwrap_err().to_string();
        assert!(err.contains("h100"), "names the offender: {err}");
        for valid in DeviceSpec::NAMES {
            assert!(err.contains(valid), "lists '{valid}': {err}");
        }
        // every advertised name round-trips
        for valid in DeviceSpec::NAMES {
            assert!(DeviceSpec::parse(valid).is_ok(), "{valid}");
        }
    }

    #[test]
    fn capacities_positive() {
        for d in [
            DeviceSpec::v100(),
            DeviceSpec::t4(),
            DeviceSpec::k80(),
            DeviceSpec::tpuv2(),
            DeviceSpec::cpu_xeon(),
        ] {
            assert!(d.block_capacity() > 0);
            assert!(d.peak_flops > 0.0 && d.mem_bw > 0.0);
            assert!(d.max_eff > 0.0 && d.max_eff <= 1.0);
        }
    }
}
