//! The three GPU multiplexing disciplines the paper compares (§4, §5):
//!
//! * **Time multiplexing** — one CUDA context at a time, kernel-granular
//!   round-robin with pipeline-flush context switches (§4.1, Fig. 4);
//! * **Spatial multiplexing** — Hyper-Q/MPS-style concurrent execution via
//!   the processor-sharing engine, with contention + anomalies (§4.2,
//!   Fig. 4/5);
//! * **VLIW coalescing** — the paper's proposal: pack the streams' current
//!   kernels into superkernels (§5, Fig. 6).
//!
//! Model-level runs respect intra-stream dependencies: layer j+1 of a
//! stream only becomes runnable when layer j completes (`ChainSim`).

use crate::gpu::cost::CostModel;
use crate::gpu::kernel::{KernelDesc, LaunchConfig};
use crate::gpu::timeline::{
    run_time_mux, Completion, SharingModel, SharingSim, SimKernel, SimResult,
};

/// A per-stream inference: an ordered chain of layer kernels.
#[derive(Debug, Clone)]
pub struct InferenceJob {
    /// Stream (tenant/replica) id.
    pub stream: u32,
    /// Layer kernels in program order.
    pub layers: Vec<KernelDesc>,
    /// Arrival time, µs.
    pub arrival_us: f64,
}

/// Per-stream completion of a whole inference.
#[derive(Debug, Clone, Copy)]
pub struct JobCompletion {
    /// Stream id.
    pub stream: u32,
    /// End-to-end inference latency, µs.
    pub latency_us: f64,
    /// Completion time, µs.
    pub end_us: f64,
    /// Number of layers that were degraded by anomalies.
    pub stragglers: u32,
}

/// Result of a model-level multiplexing run.
#[derive(Debug, Clone)]
pub struct MuxResult {
    /// One completion per job.
    pub jobs: Vec<JobCompletion>,
    /// Makespan, µs.
    pub makespan_us: f64,
    /// Time-averaged device utilization.
    pub utilization: f64,
}

impl MuxResult {
    /// Mean inference latency, µs.
    pub fn mean_latency_us(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        self.jobs.iter().map(|j| j.latency_us).sum::<f64>() / self.jobs.len() as f64
    }

    /// Max inference latency, µs.
    pub fn max_latency_us(&self) -> f64 {
        self.jobs.iter().map(|j| j.latency_us).fold(0.0, f64::max)
    }
}

// ---------------------------------------------------------------------------
// Time multiplexing (§4.1)
// ---------------------------------------------------------------------------

/// Kernel-granular round-robin across streams; the on-device scheduler
/// serializes everything and flushes the pipeline on context switches.
pub fn time_mux(cm: &CostModel, jobs: &[InferenceJob]) -> MuxResult {
    // flatten respecting round-robin interleave: take layer 0 of each
    // stream, then layer 1, ... (the fairest thing a context scheduler does)
    let max_layers = jobs.iter().map(|j| j.layers.len()).max().unwrap_or(0);
    let mut kernels = Vec::new();
    let mut id = 0u64;
    for li in 0..max_layers {
        for job in jobs {
            if let Some(k) = job.layers.get(li) {
                kernels.push(SimKernel {
                    id,
                    stream: job.stream,
                    profile: cm.profile_default(k),
                    arrival_us: job.arrival_us,
                });
                id += 1;
            }
        }
    }
    let res = run_time_mux(&kernels, cm.device.ctx_switch_us);
    finish_jobs(jobs, &res)
}

// ---------------------------------------------------------------------------
// Spatial multiplexing (§4.2) — dependency-aware processor sharing
// ---------------------------------------------------------------------------

/// Hyper-Q-style concurrent execution with intra-stream chaining: layer
/// j+1 is released the instant layer j completes. Implemented as repeated
/// rounds of the sharing engine: each round runs every stream's *current*
/// layer; a stream's next layer arrives at its previous completion time.
pub fn spatial_mux(cm: &CostModel, model: SharingModel, jobs: &[InferenceJob]) -> MuxResult {
    // Iterative release: maintain per-stream (next-layer-index, ready-time).
    // We simulate in waves but with exact release times by re-running the
    // sharing engine over the full kernel set with arrival = ready time,
    // iterating until release times fix-point (they do in ≤ L iterations
    // because layer l's completion only depends on layers ≤ l).
    let n = jobs.len();
    let max_layers = jobs.iter().map(|j| j.layers.len()).max().unwrap_or(0);
    let mut ready: Vec<Vec<f64>> = jobs
        .iter()
        .map(|j| {
            let mut v = vec![f64::INFINITY; j.layers.len() + 1];
            v[0] = j.arrival_us;
            v
        })
        .collect();
    let sim = SharingSim::new(model);
    let mut final_res: Option<SimResult> = None;
    for _round in 0..max_layers.max(1) {
        // build kernel set with current release estimates (unknown layers
        // use +inf and are excluded)
        let mut kernels = Vec::new();
        for (ji, job) in jobs.iter().enumerate() {
            for (li, k) in job.layers.iter().enumerate() {
                if ready[ji][li].is_finite() {
                    kernels.push(SimKernel {
                        id: (ji * max_layers + li) as u64,
                        stream: job.stream,
                        profile: cm.profile_default(k),
                        arrival_us: ready[ji][li],
                    });
                }
            }
        }
        let res = sim.run(&kernels);
        // update next-layer release times from completions
        let mut changed = false;
        for c in &res.completions {
            let ji = (c.id as usize) / max_layers;
            let li = (c.id as usize) % max_layers;
            if li + 1 < ready[ji].len() {
                let newt = c.end_us;
                if (ready[ji][li + 1] - newt).abs() > 1e-6 {
                    ready[ji][li + 1] = newt;
                    changed = true;
                }
            }
        }
        final_res = Some(res);
        if !changed {
            break;
        }
    }
    let res = final_res.expect("at least one round");
    // per-job: latency = last layer end − arrival
    let mut jobsout = Vec::with_capacity(n);
    for (ji, job) in jobs.iter().enumerate() {
        let mut end = job.arrival_us;
        let mut stragglers = 0u32;
        for c in &res.completions {
            let cji = (c.id as usize) / max_layers;
            if cji == ji {
                end = end.max(c.end_us);
                stragglers += c.straggler as u32;
            }
        }
        jobsout.push(JobCompletion {
            stream: job.stream,
            latency_us: end - job.arrival_us,
            end_us: end,
            stragglers,
        });
    }
    MuxResult {
        makespan_us: res.makespan_us,
        utilization: res.utilization,
        jobs: jobsout,
    }
}

// ---------------------------------------------------------------------------
// Whole-batch oracle & VLIW coalescing (§5)
// ---------------------------------------------------------------------------

/// The batched-inference oracle (Fig. 4's lower bound): all R requests for
/// the *same* model run as one batch-R inference — per layer, m scales by R.
pub fn batched_oracle(cm: &CostModel, layers: &[KernelDesc], replicas: u32) -> f64 {
    layers
        .iter()
        .map(|k| {
            let batched = KernelDesc {
                m: k.m * replicas,
                ..*k
            };
            cm.profile_default(&batched).duration_us + cm.device.layer_overhead_us
        })
        .sum()
}

/// VLIW coalescing: per layer, pack the R streams' kernels into one
/// superkernel (`problems = R`). Unlike the batch oracle this preserves
/// stream independence (no shared weights assumption beyond shape class)
/// and pays one launch per superkernel plus the JIT's packing overhead.
pub fn coalesced(
    cm: &CostModel,
    layers: &[KernelDesc],
    replicas: u32,
    cfg: &LaunchConfig,
    jit_overhead_us: f64,
) -> f64 {
    layers
        .iter()
        .map(|k| {
            let packed = KernelDesc {
                problems: k.problems * replicas,
                ..*k
            };
            cm.profile(&packed, cfg).duration_us + jit_overhead_us
        })
        .sum()
}

// ---------------------------------------------------------------------------
// Kernel-level throughput comparisons (Fig. 6 / Table 1)
// ---------------------------------------------------------------------------

/// Sustained TFLOPS when `streams` copies of `k` are executed under each
/// discipline, back-to-back for `iters` rounds.
#[derive(Debug, Clone, Copy)]
pub struct KernelTput {
    /// Time multiplexing (§4.1).
    pub time_mux_tflops: f64,
    /// Hyper-Q spatial multiplexing (§4.2).
    pub spatial_tflops: f64,
    /// VLIW coalesced superkernel (§5.3).
    pub coalesced_tflops: f64,
}

/// Fig. 6 experiment: conv2_2-class SGEMM replicated across `streams`.
pub fn kernel_throughput(
    cm: &CostModel,
    k: &KernelDesc,
    streams: u32,
    model: SharingModel,
) -> KernelTput {
    let flops_total = k.flops() * streams as f64;
    // time mux: serial + ctx switch between streams
    let kernels: Vec<SimKernel> = (0..streams)
        .map(|s| SimKernel {
            id: s as u64,
            stream: s,
            profile: cm.profile_default(k),
            arrival_us: 0.0,
        })
        .collect();
    let tm = run_time_mux(&kernels, cm.device.ctx_switch_us);
    let sp = SharingSim::new(model).run(&kernels);
    let packed = KernelDesc {
        problems: k.problems * streams,
        ..*k
    };
    let coal_us = cm.profile_default(&packed).duration_us;
    KernelTput {
        time_mux_tflops: flops_total / tm.makespan_us / 1e6,
        spatial_tflops: flops_total / sp.makespan_us / 1e6,
        coalesced_tflops: flops_total / coal_us / 1e6,
    }
}

fn finish_jobs(jobs: &[InferenceJob], res: &SimResult) -> MuxResult {
    let mut out = Vec::with_capacity(jobs.len());
    for job in jobs {
        let mine: Vec<&Completion> = res
            .completions
            .iter()
            .filter(|c| c.stream == job.stream)
            .collect();
        let end = mine.iter().map(|c| c.end_us).fold(job.arrival_us, f64::max);
        out.push(JobCompletion {
            stream: job.stream,
            latency_us: end - job.arrival_us,
            end_us: end,
            stragglers: mine.iter().filter(|c| c.straggler).count() as u32,
        });
    }
    MuxResult {
        jobs: out,
        makespan_us: res.makespan_us,
        utilization: res.utilization,
    }
}

/// Build R identical replica jobs from a layer trace (Fig. 4 workload).
pub fn replicate_jobs(layers: &[KernelDesc], replicas: u32) -> Vec<InferenceJob> {
    (0..replicas)
        .map(|s| InferenceJob {
            stream: s,
            layers: layers.to_vec(),
            arrival_us: 0.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rn18_conv2_2() -> KernelDesc {
        // ResNet-18 conv2_2 after im2col: 56*56 x (64*9) x 64
        KernelDesc::gemm(56 * 56, 64 * 9, 64)
    }

    fn small_trace() -> Vec<KernelDesc> {
        vec![
            KernelDesc::gemm(3136, 576, 64),
            KernelDesc::gemm(784, 1152, 128),
            KernelDesc::gemm(196, 2304, 256),
        ]
    }

    #[test]
    fn time_mux_latency_grows_linearly_with_replicas() {
        // Fig. 4: "inference latency increased linearly"
        let cm = CostModel::v100();
        let l1 = time_mux(&cm, &replicate_jobs(&small_trace(), 1)).mean_latency_us();
        let l4 = time_mux(&cm, &replicate_jobs(&small_trace(), 4)).mean_latency_us();
        let l8 = time_mux(&cm, &replicate_jobs(&small_trace(), 8)).mean_latency_us();
        assert!(l4 > 2.5 * l1, "l1={l1} l4={l4}");
        assert!(l8 > 1.7 * l4, "l4={l4} l8={l8}");
    }

    #[test]
    fn spatial_beats_time_mux_but_not_batched() {
        // Fig. 4 ordering: batched < spatial < time-mux
        let cm = CostModel::v100();
        let trace = small_trace();
        let r = 8;
        let tm = time_mux(&cm, &replicate_jobs(&trace, r)).mean_latency_us();
        let sp = spatial_mux(&cm, SharingModel::default(), &replicate_jobs(&trace, r))
            .mean_latency_us();
        let bo = batched_oracle(&cm, &trace, r);
        assert!(sp < tm, "spatial {sp} must beat time-mux {tm}");
        assert!(bo < sp, "batched {bo} must beat spatial {sp}");
    }

    #[test]
    fn spatial_variability_increases_with_tenants() {
        // Fig. 5: more tenants -> more per-stream latency variance
        let cm = CostModel::v100();
        let trace = small_trace();
        let cov = |r: u32| {
            let res = spatial_mux(&cm, SharingModel::default(), &replicate_jobs(&trace, r));
            let mut s = crate::util::stats::Streaming::new();
            for j in &res.jobs {
                s.push(j.latency_us);
            }
            s.cov()
        };
        assert!(cov(13) > cov(2), "cov13={} cov2={}", cov(13), cov(2));
    }

    #[test]
    fn coalesced_throughput_dominates_fig6() {
        // Fig. 6 shape: coalesced > spatial > time-mux, with the coalesced/
        // time-mux gap in the high single digits and coalesced/spatial ~2-4x
        let cm = CostModel::v100();
        let t = kernel_throughput(&cm, &rn18_conv2_2(), 9, SharingModel::default());
        assert!(t.coalesced_tflops > t.spatial_tflops);
        assert!(t.spatial_tflops > t.time_mux_tflops);
        let vs_time = t.coalesced_tflops / t.time_mux_tflops;
        let vs_spatial = t.coalesced_tflops / t.spatial_tflops;
        assert!(
            (4.0..14.0).contains(&vs_time),
            "coalesced/time-mux = {vs_time} (paper: 7.71)"
        );
        assert!(
            (1.8..6.0).contains(&vs_spatial),
            "coalesced/spatial = {vs_spatial} (paper: 3.23)"
        );
    }

    #[test]
    fn chained_spatial_respects_dependencies() {
        // a 2-layer job can never finish faster than the sum of its layers'
        // isolated durations
        let cm = CostModel::v100();
        let trace = small_trace();
        let min_sum: f64 = trace
            .iter()
            .map(|k| cm.profile_default(k).duration_us)
            .sum();
        let res = spatial_mux(&cm, SharingModel::default(), &replicate_jobs(&trace, 3));
        for j in &res.jobs {
            assert!(j.latency_us >= min_sum * 0.99, "{} < {min_sum}", j.latency_us);
        }
    }

    #[test]
    fn batched_oracle_sublinear_in_replicas() {
        let cm = CostModel::v100();
        let trace = small_trace();
        let b1 = batched_oracle(&cm, &trace, 1);
        let b8 = batched_oracle(&cm, &trace, 8);
        assert!(b8 < 6.0 * b1, "b1={b1} b8={b8}");
    }
}
