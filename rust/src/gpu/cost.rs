//! Roofline + wave-quantization cost model.
//!
//! Produces, for a (kernel, launch-config, device) triple:
//!
//! * the **isolated duration** — what the kernel takes owning the device;
//! * the **demand** — the fraction of the device it can actually exploit
//!   (the paper's utilization gap: interactive kernels have demand ≪ 1);
//! * the **attainable throughput** — `min(peak·eff, AI·BW)` per the
//!   roofline model [Williams et al. 2009], which §3 cites directly.
//!
//! The timeline engine ([`crate::gpu::timeline`]) then shares the device
//! between concurrent kernels using these profiles.

use crate::gpu::device::DeviceSpec;
use crate::gpu::kernel::{KernelDesc, LaunchConfig};

/// Cost model bound to one device.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// The device being modeled.
    pub device: DeviceSpec,
}

/// Everything the simulator needs to know about one kernel launch.
#[derive(Debug, Clone, Copy)]
pub struct KernelProfile {
    /// Isolated wall time, µs (includes launch overhead).
    pub duration_us: f64,
    /// Pure execution time without launch overhead, µs.
    pub exec_us: f64,
    /// Fraction of the device the kernel can exploit at once (0, 1].
    pub demand: f64,
    /// Co-residency pressure on shared SM state when multiplexed spatially
    /// (from the launch config's tuning; see §4.2 / Table 1).
    pub residency: f64,
    /// Attainable FLOP/s when run alone.
    pub attainable_flops: f64,
    /// Utilization vs device peak (the Fig. 3 y-axis).
    pub utilization: f64,
    /// Total FLOPs.
    pub flops: f64,
    /// True if the roofline memory ceiling binds (AI < knee).
    pub memory_bound: bool,
}

/// Clamp tile sizes to the problem (shape dispatch): never use a tile
/// larger than the next power of two covering the dimension.
fn clamp_config(cfg: &LaunchConfig, k: &KernelDesc) -> LaunchConfig {
    let np2 = |d: u32| d.max(1).next_power_of_two();
    LaunchConfig {
        tm: cfg.tm.min(np2(k.m)),
        tn: cfg.tn.min(np2(k.n)),
        tk: cfg.tk.min(np2(k.k)),
        residency: cfg.residency,
    }
}

impl CostModel {
    /// Model for a device.
    pub fn new(device: DeviceSpec) -> Self {
        CostModel { device }
    }

    /// V100 model (the paper's testbed).
    pub fn v100() -> Self {
        Self::new(DeviceSpec::v100())
    }

    /// Profile a kernel under a launch config.
    ///
    /// Tiles are first clamped to the problem (`tm' = min(tm, 2^⌈log2 m⌉)`,
    /// same for n): real GEMM libraries shape-dispatch, so an m=1 GEMV is
    /// never executed with 128-row tiles. The *clamped* config determines
    /// blocks, edge waste and ILP.
    pub fn profile(&self, k: &KernelDesc, cfg: &LaunchConfig) -> KernelProfile {
        let d = &self.device;
        let cfg = clamp_config(cfg, k);
        let cfg = &cfg;
        let blocks = cfg.blocks(k);
        let rbs = cfg.resident_blocks_per_sm(d) as u64;
        let capacity = (d.sms as u64) * rbs;

        // Spatial efficiency: a launch with B blocks can occupy at most B
        // SMs (one block keeps one SM busy; extra resident blocks per SM
        // only hide latency, which `max_eff` already folds in). Continuous
        // block-drain beyond that — superkernel grids amortize wave tails.
        let spatial_eff = (blocks as f64 / d.sms as f64).min(1.0);
        let _ = capacity;

        // Per-block efficiency: tile shape (edge waste) × ILP (tile size).
        let shape_eff = cfg.tile_efficiency(k) * cfg.ilp_efficiency();

        // Compute ceiling.
        let compute_eff = (d.max_eff * shape_eff * spatial_eff).clamp(1e-6, 1.0);
        let compute_flops = d.peak_flops * compute_eff;

        // Memory ceiling: bandwidth also needs parallelism to saturate
        // (a handful of blocks cannot keep 900 GB/s busy); ~half the SMs
        // streaming suffices (memory-level parallelism saturates earlier
        // than compute).
        let bw_sat = (blocks as f64 / (0.5 * d.sms as f64)).min(1.0);
        let mem_flops = k.arithmetic_intensity() * d.mem_bw * bw_sat.max(1e-3);

        let attainable = compute_flops.min(mem_flops).max(1.0);
        let exec_us = k.flops() / attainable * 1e6;
        let duration_us = exec_us + d.launch_us;

        KernelProfile {
            duration_us,
            exec_us,
            demand: (blocks as f64 / d.sms as f64).clamp(0.01, 1.0),
            // Co-residency pressure this launch puts on shared SM state
            // (registers/L1/L2): a property of how the kernel was *tuned*,
            // not of its size — greedy kernels assume they own the device
            // (§4.2 "kernels are tuned assuming they are single-tenant").
            residency: cfg.residency,
            attainable_flops: attainable,
            utilization: attainable / d.peak_flops,
            flops: k.flops(),
            memory_bound: mem_flops < compute_flops,
        }
    }

    /// Profile with the greedy default config (what an early-binding,
    /// context-free programmer ships — §5.1).
    pub fn profile_default(&self, k: &KernelDesc) -> KernelProfile {
        self.profile(k, &LaunchConfig::greedy())
    }

    /// Throughput (problem instances per second) if this kernel is run
    /// back-to-back alone.
    pub fn isolated_throughput(&self, k: &KernelDesc, cfg: &LaunchConfig) -> f64 {
        let p = self.profile(k, cfg);
        k.problems as f64 / (p.duration_us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v100() -> CostModel {
        CostModel::v100()
    }

    /// ResNet-50 conv-as-GEMM at batch b: a representative mid-network
    /// 3x3 conv layer (28x28x128 -> 128ch).
    fn rn50_layer(b: u32) -> KernelDesc {
        KernelDesc::gemm(b * 28 * 28, 128 * 9, 128)
    }

    #[test]
    fn batch1_underutilizes_v100() {
        // Fig. 3: interactive latencies => <25-30% of peak
        let p = v100().profile_default(&rn50_layer(1));
        assert!(
            p.utilization < 0.30,
            "batch-1 utilization {} should be <30%",
            p.utilization
        );
    }

    #[test]
    fn large_batch_improves_but_caps_below_peak() {
        // Fig. 3: "larger batch sizes struggle to achieve 40% of peak"
        let cm = v100();
        let u1 = cm.profile_default(&rn50_layer(1)).utilization;
        let u64b = cm.profile_default(&rn50_layer(64)).utilization;
        assert!(u64b > 2.0 * u1, "batching must help: {u1} -> {u64b}");
        assert!(u64b < 0.95, "never reaches peak: {u64b}");
    }

    #[test]
    fn coalescing_beats_sequential_small_kernels() {
        // the Fig. 6 mechanism: P small GEMMs coalesced as one batched
        // kernel finish faster than P isolated runs
        let cm = v100();
        let single = KernelDesc::gemm(56 * 56, 64 * 9, 64); // rn18 conv2_2
        let coal = KernelDesc::batched(8, 56 * 56, 64 * 9, 64);
        let t_seq = 8.0 * cm.profile_default(&single).duration_us;
        let t_coal = cm.profile_default(&coal).duration_us;
        assert!(
            t_coal < t_seq / 2.0,
            "coalesced {t_coal}µs vs sequential {t_seq}µs"
        );
    }

    #[test]
    fn tiny_gemv_is_memory_bound() {
        // LSTM-style matrix-vector work sits under the roofline knee
        let p = v100().profile_default(&KernelDesc::gemm(1, 1024, 1024));
        assert!(p.memory_bound);
        assert!(p.utilization < 0.05);
    }

    #[test]
    fn duration_scales_roughly_linearly_in_flops_at_scale() {
        let cm = v100();
        let a = cm.profile_default(&KernelDesc::gemm(4096, 4096, 4096));
        let b = cm.profile_default(&KernelDesc::gemm(8192, 4096, 4096));
        let ratio = b.exec_us / a.exec_us;
        assert!((1.8..2.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn demand_reflects_parallelism() {
        let cm = v100();
        let small = cm.profile_default(&KernelDesc::gemm(128, 512, 128));
        let big = cm.profile_default(&KernelDesc::gemm(8192, 512, 8192));
        assert!(small.demand < 0.05);
        assert!(big.demand >= 1.0 - 1e-9);
    }

    #[test]
    fn collaborative_config_slower_alone() {
        // Table 1: collaborative kernel is ~20% slower in isolation
        let cm = v100();
        let k = KernelDesc::batched(4, 1024, 1024, 1024);
        let tg = cm.isolated_throughput(&k, &LaunchConfig::greedy());
        let tc = cm.isolated_throughput(&k, &LaunchConfig::collaborative());
        assert!(tc < tg, "collab {tc} must be < greedy {tg} in isolation");
        assert!(tc > 0.5 * tg, "but not catastrophically slower");
    }

    #[test]
    fn cpu_is_orders_slower_than_v100() {
        let cpu = CostModel::new(DeviceSpec::cpu_xeon());
        let v = v100();
        let k = rn50_layer(1);
        let t_cpu = cpu.profile_default(&k).duration_us;
        let t_gpu = v.profile_default(&k).duration_us;
        assert!(t_cpu > 10.0 * t_gpu, "cpu {t_cpu}µs vs gpu {t_gpu}µs");
    }
}
