//! Discrete-event processor-sharing engine: kernels occupy GPU space-time.
//!
//! Concurrent kernels (spatial multiplexing / Hyper-Q) share the device
//! under *water-filling*: each active kernel i has demand `d_i` (the
//! fraction of the device it can exploit, from [`crate::gpu::cost`]) and
//! receives an allocation `a_i ≤ d_i` with `Σ a_i ≤ 1`, progressing at rate
//! `a_i / d_i` of its isolated speed. Oversubscription (`Σ d_i > 1`) adds a
//! contention penalty (cache/DRAM thrash + stream-scheduler serialization),
//! and a seeded **anomaly model** turns a few kernels into stragglers —
//! reproducing the paper's §4.2/Fig. 5 unpredictability, and the §5.2
//! observation that anomalies "typically only create a few stragglers".

use crate::gpu::cost::KernelProfile;
use crate::util::rng::Rng;

/// A kernel instance submitted to the simulator.
#[derive(Debug, Clone)]
pub struct SimKernel {
    /// Unique id.
    pub id: u64,
    /// Execution stream (tenant / process) this kernel belongs to.
    pub stream: u32,
    /// Cost-model profile (isolated duration, demand, ...).
    pub profile: KernelProfile,
    /// Arrival time, µs.
    pub arrival_us: f64,
}

/// A finished kernel.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Kernel id.
    pub id: u64,
    /// Stream id.
    pub stream: u32,
    /// When the kernel first received device time, µs.
    pub start_us: f64,
    /// Completion time, µs.
    pub end_us: f64,
    /// End-to-end latency including queueing, µs.
    pub latency_us: f64,
    /// True if the anomaly model degraded this kernel.
    pub straggler: bool,
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Per-kernel completions (sorted by end time).
    pub completions: Vec<Completion>,
    /// Makespan, µs (last completion − first arrival).
    pub makespan_us: f64,
    /// Time-averaged device allocation in [0,1] (the utilization metric).
    pub utilization: f64,
}

impl SimResult {
    /// Mean latency over all completions, µs.
    pub fn mean_latency_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.latency_us).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Throughput in kernels/s over the makespan.
    pub fn throughput(&self) -> f64 {
        if self.makespan_us <= 0.0 {
            return 0.0;
        }
        self.completions.len() as f64 / (self.makespan_us / 1e6)
    }
}

/// Tunable contention/anomaly behaviour for spatial sharing.
#[derive(Debug, Clone)]
pub struct SharingModel {
    /// Contention penalty slope: rate multiplier `1/(1+α·max(0, P−1))`
    /// where `P = Σ residency` over active kernels — co-resident kernels
    /// tuned for whole-GPU occupancy thrash shared SM state (§4.2,
    /// Table 1: greedy kernels multiplex at 4.5 TFLOPS where collaborative
    /// kernels reach 6.1).
    pub contention_alpha: f64,
    /// Baseline probability a kernel becomes a straggler per extra tenant.
    pub anomaly_per_tenant: f64,
    /// Extra straggler probability when the active tenant count is odd
    /// (§4.2: "odd number of tenants ... greater variability").
    pub odd_tenant_bonus: f64,
    /// Straggler rate multiplier (fraction of normal speed).
    pub straggler_slowdown: f64,
    /// RNG seed for anomaly draws.
    pub seed: u64,
}

impl Default for SharingModel {
    fn default() -> Self {
        SharingModel {
            contention_alpha: 0.65,
            anomaly_per_tenant: 0.015,
            odd_tenant_bonus: 0.05,
            straggler_slowdown: 0.35,
            seed: 0xC0FFEE,
        }
    }
}

struct Active {
    idx: usize,
    start_us: f64,
    remaining: f64, // in "isolated-µs of pure exec"
    demand: f64,
    residency: f64,
    straggler: bool,
}

/// Processor-sharing simulator over one device.
pub struct SharingSim {
    /// Behaviour knobs.
    pub model: SharingModel,
}

impl SharingSim {
    /// New simulator with a sharing model.
    pub fn new(model: SharingModel) -> Self {
        SharingSim { model }
    }

    /// Default model.
    pub fn default_model() -> Self {
        Self::new(SharingModel::default())
    }

    /// Run kernels to completion under spatial sharing.
    ///
    /// Each kernel additionally pays its launch overhead serially at start
    /// (launches funnel through one stream-scheduler queue).
    pub fn run(&self, kernels: &[SimKernel]) -> SimResult {
        // Straggler status is PER-STREAM (a degraded worker, §5.2), drawn
        // deterministically from (seed, stream) the first time the stream
        // is seen; the draw probability reflects tenancy at that moment.
        let mut stream_straggler: std::collections::HashMap<u32, bool> =
            std::collections::HashMap::new();
        let mut rng = Rng::new(self.model.seed);
        let n = kernels.len();
        if n == 0 {
            return SimResult {
                completions: vec![],
                makespan_us: 0.0,
                utilization: 0.0,
            };
        }
        // arrival order
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            kernels[a]
                .arrival_us
                .partial_cmp(&kernels[b].arrival_us)
                .unwrap()
        });
        let mut next_arrival = 0usize;
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<Completion> = Vec::with_capacity(n);
        let mut now = kernels[order[0]].arrival_us;
        let first_arrival = now;
        let mut busy_integral = 0.0; // ∫ Σa dt
        // distinct tenants ever active concurrently → anomaly prob input
        loop {
            // admit arrivals at `now`
            while next_arrival < n && kernels[order[next_arrival]].arrival_us <= now + 1e-9 {
                let idx = order[next_arrival];
                let k = &kernels[idx];
                let tenants = active.len() + 1;
                let mut p = self.model.anomaly_per_tenant * (tenants.saturating_sub(1)) as f64;
                if tenants > 1 && tenants % 2 == 1 {
                    p += self.model.odd_tenant_bonus;
                }
                let straggler = *stream_straggler.entry(k.stream).or_insert_with(|| {
                    let mut sr = crate::util::rng::Rng::new(
                        self.model.seed ^ (k.stream as u64).wrapping_mul(0x9E3779B97F4A7C15),
                    );
                    let _ = rng.next_u64(); // keep the shared stream advancing
                    sr.f64() < p.min(0.9)
                });
                active.push(Active {
                    idx,
                    start_us: now,
                    remaining: k.profile.duration_us, // exec + launch
                    demand: k.profile.demand,
                    residency: k.profile.residency,
                    straggler,
                });
                next_arrival += 1;
            }
            if active.is_empty() {
                if next_arrival >= n {
                    break;
                }
                now = kernels[order[next_arrival]].arrival_us;
                continue;
            }

            // --- allocate: water-filling capped by demand ---
            let total_demand: f64 = active.iter().map(|a| a.demand).sum();
            // co-residency pressure from how the kernels were tuned
            let pressure: f64 = active.iter().map(|a| a.residency).sum();
            let contention =
                1.0 / (1.0 + self.model.contention_alpha * (pressure - 1.0).max(0.0));
            // proportional fill
            let scale = if total_demand > 1.0 {
                1.0 / total_demand
            } else {
                1.0
            };
            // rate_i = (a_i / d_i) * contention * straggler_factor
            // with a_i = d_i * scale  =>  rate_i = scale * contention * sf
            let mut min_dt = f64::INFINITY;
            for a in &active {
                let sf = if a.straggler {
                    self.model.straggler_slowdown
                } else {
                    1.0
                };
                let rate = scale * contention * sf;
                min_dt = min_dt.min(a.remaining / rate);
            }
            // next event: earliest completion or next arrival
            let dt = if next_arrival < n {
                let ta = kernels[order[next_arrival]].arrival_us - now;
                min_dt.min(ta.max(0.0))
            } else {
                min_dt
            };
            // progress everyone
            let alloc_sum: f64 = active.iter().map(|a| a.demand * scale).sum::<f64>();
            busy_integral += alloc_sum.min(1.0) * dt;
            for a in &mut active {
                let sf = if a.straggler {
                    self.model.straggler_slowdown
                } else {
                    1.0
                };
                a.remaining -= scale * contention * sf * dt;
            }
            now += dt;
            // harvest completions
            let mut i = 0;
            while i < active.len() {
                if active[i].remaining <= 1e-6 {
                    let a = active.swap_remove(i);
                    let k = &kernels[a.idx];
                    done.push(Completion {
                        id: k.id,
                        stream: k.stream,
                        start_us: a.start_us,
                        end_us: now,
                        latency_us: now - k.arrival_us,
                        straggler: a.straggler,
                    });
                } else {
                    i += 1;
                }
            }
            if done.len() == n {
                break;
            }
        }
        done.sort_by(|a, b| a.end_us.partial_cmp(&b.end_us).unwrap());
        let makespan = done.last().map(|c| c.end_us - first_arrival).unwrap_or(0.0);
        SimResult {
            utilization: if makespan > 0.0 {
                busy_integral / makespan
            } else {
                0.0
            },
            completions: done,
            makespan_us: makespan,
        }
    }
}

/// Strictly sequential execution with context-switch flush between kernels
/// of *different* streams (§4.1 time multiplexing).
pub fn run_time_mux(kernels: &[SimKernel], ctx_switch_us: f64) -> SimResult {
    let mut order: Vec<usize> = (0..kernels.len()).collect();
    order.sort_by(|&a, &b| {
        kernels[a]
            .arrival_us
            .partial_cmp(&kernels[b].arrival_us)
            .unwrap()
    });
    let mut now = 0.0f64;
    let mut last_stream: Option<u32> = None;
    let mut done = Vec::with_capacity(kernels.len());
    let mut busy = 0.0;
    let mut first_arrival = f64::INFINITY;
    for &i in &order {
        let k = &kernels[i];
        first_arrival = first_arrival.min(k.arrival_us);
        now = now.max(k.arrival_us);
        if last_stream.is_some() && last_stream != Some(k.stream) {
            now += ctx_switch_us;
        }
        let start = now;
        now += k.profile.duration_us;
        busy += k.profile.duration_us * k.profile.demand.min(1.0);
        done.push(Completion {
            id: k.id,
            stream: k.stream,
            start_us: start,
            end_us: now,
            latency_us: now - k.arrival_us,
            straggler: false,
        });
        last_stream = Some(k.stream);
    }
    let makespan = if done.is_empty() {
        0.0
    } else {
        done.last().unwrap().end_us - first_arrival
    };
    SimResult {
        completions: done,
        makespan_us: makespan,
        utilization: if makespan > 0.0 { busy / makespan } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::cost::CostModel;
    use crate::gpu::kernel::KernelDesc;

    fn kern(id: u64, stream: u32, arrival: f64, m: u32) -> SimKernel {
        let cm = CostModel::v100();
        SimKernel {
            id,
            stream,
            profile: cm.profile_default(&KernelDesc::gemm(m, 576, 64)),
            arrival_us: arrival,
        }
    }

    #[test]
    fn single_kernel_runs_at_isolated_speed() {
        let k = kern(0, 0, 0.0, 3136);
        let res = SharingSim::default_model().run(&[k.clone()]);
        assert_eq!(res.completions.len(), 1);
        let c = res.completions[0];
        assert!(
            (c.latency_us - k.profile.duration_us).abs() / k.profile.duration_us < 0.01,
            "latency {} vs isolated {}",
            c.latency_us,
            k.profile.duration_us
        );
    }

    #[test]
    fn two_small_kernels_overlap() {
        // both fit: makespan ≈ single duration, not 2x
        let a = kern(0, 0, 0.0, 512);
        let b = kern(1, 1, 0.0, 512);
        let solo = a.profile.duration_us;
        let res = SharingSim::default_model().run(&[a, b]);
        assert!(res.makespan_us < 1.5 * solo, "makespan {}", res.makespan_us);
    }

    #[test]
    fn oversubscription_slows_everyone() {
        let kerns: Vec<SimKernel> = (0..12).map(|i| kern(i, i as u32, 0.0, 3136)).collect();
        let solo = kerns[0].profile.duration_us;
        let res = SharingSim::default_model().run(&kerns);
        // 12 co-resident greedy kernels heavily oversubscribe the device
        assert!(res.makespan_us > 1.5 * solo);
        // but still beat the time-mux worst case (serial + ctx flush)
        let serial = 12.0 * solo + 11.0 * 200.0;
        assert!(res.makespan_us < serial, "{} vs serial {serial}", res.makespan_us);
    }

    #[test]
    fn time_mux_serializes_and_pays_context_switches() {
        let kerns: Vec<SimKernel> = (0..4).map(|i| kern(i, i as u32, 0.0, 3136)).collect();
        let solo = kerns[0].profile.duration_us;
        let res = run_time_mux(&kerns, 80.0);
        let expect = 4.0 * solo + 3.0 * 80.0;
        assert!(
            (res.makespan_us - expect).abs() < 1.0,
            "makespan {} vs {expect}",
            res.makespan_us
        );
        // mean latency grows linearly with replica index (Fig. 4)
        let lat: Vec<f64> = res.completions.iter().map(|c| c.latency_us).collect();
        assert!(lat[3] > 3.0 * lat[0]);
    }

    #[test]
    fn time_mux_same_stream_no_switch() {
        let kerns: Vec<SimKernel> = (0..3).map(|i| kern(i, 7, 0.0, 1024)).collect();
        let solo = kerns[0].profile.duration_us;
        let res = run_time_mux(&kerns, 80.0);
        assert!((res.makespan_us - 3.0 * solo).abs() < 1.0);
    }

    #[test]
    fn anomalies_are_deterministic_per_seed() {
        let kerns: Vec<SimKernel> = (0..20).map(|i| kern(i, i as u32, 0.0, 2048)).collect();
        let r1 = SharingSim::default_model().run(&kerns);
        let r2 = SharingSim::default_model().run(&kerns);
        let s1: Vec<bool> = r1.completions.iter().map(|c| c.straggler).collect();
        let s2: Vec<bool> = r2.completions.iter().map(|c| c.straggler).collect();
        assert_eq!(s1, s2);
    }

    #[test]
    fn stragglers_increase_with_tenancy() {
        let mut model = SharingModel::default();
        model.anomaly_per_tenant = 0.04;
        let few: Vec<SimKernel> = (0..2).map(|i| kern(i, i as u32, 0.0, 2048)).collect();
        let many: Vec<SimKernel> = (0..200)
            .map(|i| kern(i, (i % 16) as u32, (i / 16) as f64 * 10.0, 2048))
            .collect();
        let rf = SharingSim::new(model.clone()).run(&few);
        let rm = SharingSim::new(model).run(&many);
        let sf = rf.completions.iter().filter(|c| c.straggler).count();
        let sm = rm.completions.iter().filter(|c| c.straggler).count();
        assert!(sm as f64 / 200.0 > sf as f64 / 2.0);
    }

    #[test]
    fn arrivals_respected() {
        let a = kern(0, 0, 0.0, 1024);
        let b = kern(1, 1, 1e6, 1024); // arrives 1s later
        let res = SharingSim::default_model().run(&[a, b]);
        let cb = res.completions.iter().find(|c| c.id == 1).unwrap();
        assert!(cb.start_us >= 1e6);
    }

    #[test]
    fn utilization_bounded() {
        let kerns: Vec<SimKernel> = (0..8).map(|i| kern(i, i as u32, 0.0, 3136)).collect();
        let res = SharingSim::default_model().run(&kerns);
        assert!(res.utilization > 0.0 && res.utilization <= 1.0 + 1e-9);
    }
}
