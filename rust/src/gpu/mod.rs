//! Space-time GPU simulator — the substrate substituting for the paper's
//! V100 testbed (see DESIGN.md §2 for the substitution argument).
//!
//! The simulator is a *first-order resource-occupancy* model:
//!
//! * [`device`] — device specs (V100, T4, K80, TPU-v2-like, Xeon-class CPU)
//!   with peak FLOPS, memory bandwidth, SM counts and switching overheads;
//! * [`kernel`] — kernel descriptors (batched GEMM) and launch (blocking)
//!   configurations, with FLOP/byte/block accounting;
//! * [`cost`] — the roofline + wave-quantization cost model producing
//!   isolated kernel durations and attainable throughput;
//! * [`timeline`] — a processor-sharing discrete-event engine that executes
//!   kernels in GPU space-time, with scheduling-anomaly (straggler)
//!   injection to reproduce the paper's Fig. 4/5 unpredictability;
//! * [`multiplex`] — the three execution disciplines the paper compares:
//!   time multiplexing, Hyper-Q-style spatial multiplexing, and VLIW
//!   coalescing.

pub mod cost;
pub mod device;
pub mod kernel;
pub mod multiplex;
pub mod timeline;

pub use cost::CostModel;
pub use device::DeviceSpec;
pub use kernel::{KernelDesc, LaunchConfig};
