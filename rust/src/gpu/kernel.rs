//! Kernel descriptors and launch (blocking) configurations.
//!
//! Everything the JIT schedules reduces to *batched GEMM*: convolutions are
//! im2col'd by `model::layers`, LSTM cells are GEMV stacks, attention is QKV
//! GEMMs — exactly the paper's observation that "the set of operations to
//! coalesce is restricted largely to algebraic tensor operations".

use crate::gpu::device::DeviceSpec;

/// A batched-GEMM kernel: `problems` independent (m × k) · (k × n) products.
/// `problems > 1` is a *superkernel* (the VLIW long instruction word).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelDesc {
    /// Independent problems packed in this launch (cublasSgemmBatched-style).
    pub problems: u32,
    /// Rows of each left operand (batch·spatial after im2col).
    pub m: u32,
    /// Contraction depth.
    pub k: u32,
    /// Columns of each right operand (output channels).
    pub n: u32,
    /// Bytes per element (4 = f32).
    pub dtype_bytes: u32,
}

impl KernelDesc {
    /// Single-problem f32 GEMM.
    pub fn gemm(m: u32, k: u32, n: u32) -> Self {
        KernelDesc {
            problems: 1,
            m,
            k,
            n,
            dtype_bytes: 4,
        }
    }

    /// Batched/coalesced f32 GEMM.
    pub fn batched(problems: u32, m: u32, k: u32, n: u32) -> Self {
        KernelDesc {
            problems,
            m,
            k,
            n,
            dtype_bytes: 4,
        }
    }

    /// Total floating-point work (multiply-adds × 2).
    pub fn flops(&self) -> f64 {
        2.0 * self.problems as f64 * self.m as f64 * self.k as f64 * self.n as f64
    }

    /// Minimum HBM traffic: read A and B once, write C once.
    pub fn bytes(&self) -> f64 {
        self.problems as f64
            * self.dtype_bytes as f64
            * (self.m as f64 * self.k as f64
                + self.k as f64 * self.n as f64
                + self.m as f64 * self.n as f64)
    }

    /// Arithmetic intensity (FLOP/byte) — roofline x-coordinate.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() / self.bytes()
    }

    /// Pad this problem up to a class shape (coalescer use). Returns the
    /// padded descriptor; padding never shrinks.
    pub fn pad_to(&self, m: u32, k: u32, n: u32) -> KernelDesc {
        KernelDesc {
            problems: self.problems,
            m: self.m.max(m),
            k: self.k.max(k),
            n: self.n.max(n),
            dtype_bytes: self.dtype_bytes,
        }
    }
}

/// A blocking configuration — the GPU-side analogue of the Pallas
/// `BlockConfig` in `python/compile/kernels/coalesced_matmul.py`. The
/// autotuner (Table 1) searches over these.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchConfig {
    /// Output-tile rows per block.
    pub tm: u32,
    /// Output-tile cols per block.
    pub tn: u32,
    /// Contraction slab per iteration.
    pub tk: u32,
    /// Fraction of one SM's register/shared-memory budget a resident block
    /// consumes. Greedy kernels hog (~0.5, so 2 blocks/SM); collaborative
    /// kernels leave room for co-tenants (§5.3 / Table 1).
    pub residency: f64,
}

impl LaunchConfig {
    /// The "greedy" single-tenant-optimal config (Table 1 row 1).
    pub fn greedy() -> Self {
        LaunchConfig {
            tm: 128,
            tn: 128,
            tk: 32,
            residency: 0.50,
        }
    }

    /// The "collaborative" co-tenancy-optimal config (Table 1 row 2).
    pub fn collaborative() -> Self {
        LaunchConfig {
            tm: 64,
            tn: 64,
            tk: 32,
            residency: 0.20,
        }
    }

    /// Blocks this config launches for a kernel (wave math input).
    pub fn blocks(&self, k: &KernelDesc) -> u64 {
        let mt = (k.m as u64).div_ceil(self.tm as u64);
        let nt = (k.n as u64).div_ceil(self.tn as u64);
        k.problems as u64 * mt * nt
    }

    /// Tile efficiency: how much of each tile's FLOP slots do real elements
    /// fill (edge-tile waste). 1.0 when tiles divide the problem exactly.
    pub fn tile_efficiency(&self, k: &KernelDesc) -> f64 {
        let cover = |dim: u32, tile: u32| -> f64 {
            let tiles = (dim as u64).div_ceil(tile as u64);
            dim as f64 / (tiles * tile as u64) as f64
        };
        cover(k.m, self.tm) * cover(k.n, self.tn)
    }

    /// Per-block instruction-level efficiency: bigger tiles amortize
    /// loads/stores over more FMAs. Saturates at 128×128 (the paper's
    /// "throughput-optimal convolutional block size" observation, §5).
    pub fn ilp_efficiency(&self) -> f64 {
        let area = (self.tm * self.tn) as f64;
        let full = (128 * 128) as f64;
        // sqrt: diminishing returns as tiles grow
        (area / full).sqrt().min(1.0).max(0.25)
    }

    /// Max resident blocks per SM under this config's residency demand.
    pub fn resident_blocks_per_sm(&self, d: &DeviceSpec) -> u32 {
        ((1.0 / self.residency).floor() as u32).clamp(1, d.blocks_per_sm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_and_bytes() {
        let k = KernelDesc::gemm(64, 128, 32);
        assert_eq!(k.flops(), 2.0 * 64.0 * 128.0 * 32.0);
        assert_eq!(k.bytes(), 4.0 * (64.0 * 128.0 + 128.0 * 32.0 + 64.0 * 32.0));
        let b = KernelDesc::batched(4, 64, 128, 32);
        assert_eq!(b.flops(), 4.0 * k.flops());
        assert_eq!(b.bytes(), 4.0 * k.bytes());
    }

    #[test]
    fn arithmetic_intensity_grows_with_m() {
        // the Fig. 3 mechanism: small batch (small m) => low intensity
        let small = KernelDesc::gemm(1, 1024, 1024).arithmetic_intensity();
        let big = KernelDesc::gemm(256, 1024, 1024).arithmetic_intensity();
        assert!(small < 1.0, "ai(batch=1)={small}");
        assert!(big > 50.0, "ai(batch=256)={big}");
    }

    #[test]
    fn blocks_and_tile_efficiency() {
        let cfg = LaunchConfig::greedy();
        let k = KernelDesc::gemm(256, 512, 256);
        assert_eq!(cfg.blocks(&k), 2 * 2);
        assert_eq!(cfg.tile_efficiency(&k), 1.0);
        // ragged: 130x130 output in 128-tiles wastes most of 4 tiles
        let ragged = KernelDesc::gemm(130, 512, 130);
        assert_eq!(cfg.blocks(&ragged), 4);
        assert!(cfg.tile_efficiency(&ragged) < 0.3);
    }

    #[test]
    fn collaborative_trades_ilp_for_residency() {
        let g = LaunchConfig::greedy();
        let c = LaunchConfig::collaborative();
        assert!(c.ilp_efficiency() < g.ilp_efficiency());
        let d = DeviceSpec::v100();
        assert!(c.resident_blocks_per_sm(&d) > g.resident_blocks_per_sm(&d));
    }

    #[test]
    fn pad_never_shrinks() {
        let k = KernelDesc::gemm(100, 300, 50);
        let p = k.pad_to(64, 512, 64);
        assert_eq!((p.m, p.k, p.n), (100, 512, 64));
    }
}
