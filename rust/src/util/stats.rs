//! Streaming statistics, exact quantiles, and fixed-bucket latency
//! histograms — the measurement substrate for SLO attainment (Fig. 5),
//! latency distributions (Fig. 4) and the bench harness.

/// Streaming mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Coefficient of variation (std/mean) — the Fig. 5 "unpredictability"
    /// metric.
    pub fn cov(&self) -> f64 {
        if self.mean().abs() < 1e-12 {
            0.0
        } else {
            self.std() / self.mean()
        }
    }

    /// Minimum observation (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation (-inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantile estimator: stores samples, sorts on query. Fine for the
/// ≤10^6-sample runs the benches produce; the serving path uses [`LatencyHist`].
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
}

impl Quantiles {
    /// Empty estimator.
    pub fn new() -> Self {
        Self { xs: Vec::new() }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    /// Number of observations.
    pub fn count(&self) -> usize {
        self.xs.len()
    }

    /// q-quantile (nearest-rank, q in [0,1]); 0 when empty.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs
            .sort_unstable_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
        let idx = ((self.xs.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        self.xs[idx]
    }

    /// Convenience p50/p99 pair.
    pub fn p50_p99(&mut self) -> (f64, f64) {
        (self.quantile(0.50), self.quantile(0.99))
    }

    /// Mean of all observations.
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Log-bucketed latency histogram (HdrHistogram-lite): fixed memory,
/// ~4% relative error, used on the serving hot path where storing every
/// sample would allocate.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    /// bucket i covers [lo * g^i, lo * g^(i+1))
    counts: Vec<u64>,
    lo_us: f64,
    growth: f64,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// Buckets spanning 1µs .. ~100s with 4% growth.
    pub fn new() -> Self {
        Self::with_range(1.0, 1.04, 480)
    }

    /// Custom range: `lo_us` first bucket edge, geometric `growth`, `n` buckets.
    pub fn with_range(lo_us: f64, growth: f64, n: usize) -> Self {
        Self {
            counts: vec![0; n],
            lo_us,
            growth,
            total: 0,
            sum_us: 0.0,
            max_us: 0.0,
        }
    }

    fn bucket(&self, us: f64) -> usize {
        if us < self.lo_us {
            return 0;
        }
        let b = (us / self.lo_us).ln() / self.growth.ln();
        (b as usize).min(self.counts.len() - 1)
    }

    /// Record one latency in microseconds.
    pub fn record_us(&mut self, us: f64) {
        let b = self.bucket(us);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean latency (µs).
    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Max latency (µs).
    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate quantile (µs): the upper edge of the bucket holding the
    /// target rank, clamped to the recorded max. Reporting the *upper*
    /// edge keeps the pair consistent with [`LatencyHist::frac_leq`]:
    /// `frac_leq(quantile_us(q)) >= q` always holds, because `frac_leq`
    /// counts exactly the buckets whose upper edge is within the limit.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                if i + 1 == self.counts.len() {
                    // the overflow bucket is unbounded above — its nominal
                    // edge would under-report; the recorded max is its
                    // true upper bound (and frac_leq(max) = 1 exactly)
                    return self.max_us;
                }
                let edge = self.lo_us * self.growth.powi(i as i32 + 1);
                // the true value is ≤ both the bucket's upper edge and the
                // recorded max
                return edge.min(self.max_us);
            }
        }
        self.max_us
    }

    /// Fraction of samples at or below `limit_us` — SLO attainment.
    ///
    /// Counts only buckets whose *upper* edge is ≤ the limit. Counting the
    /// whole bucket containing `limit_us` (the old behavior) credited up
    /// to one ~4% bucket of samples strictly above the SLO, inflating
    /// attainment; the bucketed answer is now a lower bound on the truth.
    pub fn frac_leq(&self, limit_us: f64) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        if limit_us >= self.max_us {
            return 1.0; // every recorded sample is ≤ the limit, exactly
        }
        let mut full_buckets = 0usize;
        if limit_us >= self.lo_us {
            // bucket i covers [lo·g^i, lo·g^(i+1)): include i while its
            // upper edge lo·g^(i+1) ≤ limit (epsilon forgives float error
            // when the limit sits exactly on an edge)
            let b = (limit_us / self.lo_us).ln() / self.growth.ln() + 1e-9;
            // cap below the overflow bucket: it is unbounded above, so it
            // only counts via the max_us shortcut
            full_buckets = (b.floor() as usize).min(self.counts.len() - 1);
        }
        let acc: u64 = self.counts[..full_buckets].iter().sum();
        acc as f64 / self.total as f64
    }

    /// Merge another histogram (same geometry) into this one.
    pub fn merge(&mut self, other: &LatencyHist) {
        assert_eq!(self.counts.len(), other.counts.len(), "geometry mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Geometric mean of a slice (the paper reports geo-mean speedups, Fig. 6).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Exponentially-weighted moving average with an explicit observation
/// count. The count (not a magic value) distinguishes "never observed"
/// from a genuine ~0 observation, so callers fall back to their prior only
/// while `value()` is `None`.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    value: f64,
    alpha: f64,
    count: u64,
}

impl Ewma {
    /// New estimator with smoothing factor `alpha` in (0, 1].
    pub fn new(alpha: f64) -> Self {
        Ewma {
            value: 0.0,
            alpha,
            count: 0,
        }
    }

    /// Fold in one observation.
    pub fn observe(&mut self, x: f64) {
        self.value = if self.count == 0 {
            x
        } else {
            self.alpha * x + (1.0 - self.alpha) * self.value
        };
        self.count += 1;
    }

    /// Current estimate, or `None` before the first observation.
    pub fn value(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.value)
        }
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_moments() {
        let mut s = Streaming::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn streaming_cov_zero_mean_guard() {
        let mut s = Streaming::new();
        s.push(0.0);
        s.push(0.0);
        assert_eq!(s.cov(), 0.0);
    }

    #[test]
    fn quantiles_nearest_rank() {
        let mut q = Quantiles::new();
        for i in 1..=100 {
            q.push(i as f64);
        }
        assert_eq!(q.quantile(0.0), 1.0);
        assert_eq!(q.quantile(1.0), 100.0);
        assert!((q.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((q.quantile(0.99) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn quantiles_empty_is_zero() {
        let mut q = Quantiles::new();
        assert_eq!(q.quantile(0.5), 0.0);
        assert_eq!(q.mean(), 0.0);
    }

    #[test]
    fn hist_quantile_within_bucket_error() {
        let mut h = LatencyHist::new();
        for i in 1..=10_000u64 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        assert!((p50 - 5_000.0).abs() / 5_000.0 < 0.08, "p50={p50}");
        let p99 = h.quantile_us(0.99);
        assert!((p99 - 9_900.0).abs() / 9_900.0 < 0.08, "p99={p99}");
        assert!((h.mean_us() - 5_000.5).abs() < 1.0);
    }

    #[test]
    fn hist_slo_attainment() {
        let mut h = LatencyHist::new();
        for _ in 0..90 {
            h.record_us(1_000.0);
        }
        for _ in 0..10 {
            h.record_us(100_000.0);
        }
        let att = h.frac_leq(10_000.0);
        assert!((att - 0.9).abs() < 0.02, "att={att}");
    }

    #[test]
    fn frac_leq_excludes_bucket_straddling_the_limit() {
        // regression: buckets [100,200) and [200,400); samples at 150 and
        // 300. A 250µs SLO sits inside the second bucket — the old code
        // counted the whole straddling bucket and reported 100% attainment
        // even though the 300µs sample misses the SLO.
        let mut h = LatencyHist::with_range(100.0, 2.0, 10);
        h.record_us(150.0);
        h.record_us(300.0);
        assert_eq!(h.frac_leq(250.0), 0.5, "the 300µs sample is not ≤ 250µs");
        // a limit exactly on a bucket edge counts every bucket below it
        assert_eq!(h.frac_leq(200.0), 0.5);
        assert_eq!(h.frac_leq(400.0), 1.0);
        // a limit below the first bucket edge counts nothing
        assert_eq!(h.frac_leq(99.0), 0.0);
        // a limit at/above the recorded max is exact
        assert_eq!(h.frac_leq(300.0), 1.0);
    }

    #[test]
    fn quantile_edge_consistent_with_frac_leq() {
        // the reported quantile edge must attain its own rank:
        // frac_leq(quantile_us(q)) >= q for any q
        let mut h = LatencyHist::new();
        for i in 0..1000u64 {
            h.record_us(10.0 + (i as f64) * 97.0); // spread over many buckets
        }
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            let edge = h.quantile_us(q);
            let attained = h.frac_leq(edge);
            assert!(
                attained >= q,
                "q={q}: edge {edge} attains only {attained}"
            );
        }
        // the quantile never exceeds the recorded max
        assert!(h.quantile_us(1.0) <= h.max_us());
    }

    #[test]
    fn overflow_bucket_quantile_reports_recorded_max() {
        // a rank landing in the unbounded overflow bucket must report the
        // recorded max, not the (far smaller) nominal bucket edge — and
        // stay consistent with frac_leq
        let mut h = LatencyHist::with_range(100.0, 2.0, 2); // [100,200), [200,∞)
        h.record_us(150.0);
        h.record_us(10_000.0); // overflow bucket
        assert_eq!(h.quantile_us(1.0), 10_000.0, "p100 is the recorded max");
        assert_eq!(h.frac_leq(h.quantile_us(1.0)), 1.0);
        assert_eq!(h.frac_leq(h.quantile_us(0.5)), 0.5);
    }

    #[test]
    fn hist_merge_adds_counts() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record_us(10.0);
        b.record_us(20.0);
        b.record_us(30.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_us(), 30.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[7.71]) - 7.71).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn ewma_unobserved_is_none() {
        let e = Ewma::new(0.3);
        assert_eq!(e.value(), None);
        assert_eq!(e.count(), 0);
    }

    #[test]
    fn ewma_zero_observation_is_a_real_estimate() {
        // regression: a genuine 0-valued measurement must not look like
        // "never observed" and pin callers to their prior forever
        let mut e = Ewma::new(0.3);
        e.observe(0.0);
        assert_eq!(e.value(), Some(0.0));
        assert_eq!(e.count(), 1);
        e.observe(10.0);
        let v = e.value().unwrap();
        assert!((v - 3.0).abs() < 1e-9, "v={v}");
    }

    #[test]
    fn ewma_tracks_observations() {
        let mut e = Ewma::new(0.5);
        e.observe(100.0);
        assert_eq!(e.value(), Some(100.0));
        e.observe(200.0);
        assert_eq!(e.value(), Some(150.0));
        assert_eq!(e.count(), 2);
    }
}
