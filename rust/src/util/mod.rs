//! In-repo substrates (the offline crate cache has no rand/serde/clap/
//! tokio/criterion, so the pieces a serving system needs are built here).

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Seconds → microseconds as u64 (saturating; sim time is µs everywhere).
pub fn secs_to_us(s: f64) -> u64 {
    if s <= 0.0 {
        0
    } else {
        (s * 1e6).round() as u64
    }
}

/// Microseconds → milliseconds as f64 (reporting convenience).
pub fn us_to_ms(us: u64) -> f64 {
    us as f64 / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_round_trip() {
        assert_eq!(secs_to_us(1.5), 1_500_000);
        assert_eq!(secs_to_us(0.0), 0);
        assert_eq!(secs_to_us(-3.0), 0);
        assert!((us_to_ms(1500) - 1.5).abs() < 1e-12);
    }
}
