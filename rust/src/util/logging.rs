//! Leveled stderr logger (no `log`/`env_logger` needed on the hot path —
//! macro calls compile to a branch on a relaxed atomic).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// + warnings.
    Warn = 1,
    /// + high-level lifecycle events (default).
    Info = 2,
    /// + per-batch scheduling decisions.
    Debug = 3,
    /// + per-kernel detail.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Set from a string ("error".."trace"); unknown values keep the default.
pub fn set_level_str(s: &str) {
    let l = match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => return,
    };
    set_level(l);
}

/// Is this level enabled?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Internal: emit one line.
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{tag}] {args}");
}

/// Log at Info.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Info) {
            $crate::util::logging::emit($crate::util::logging::Level::Info, format_args!($($t)*));
        }
    };
}

/// Log at Warn.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Warn) {
            $crate::util::logging::emit($crate::util::logging::Level::Warn, format_args!($($t)*));
        }
    };
}

/// Log at Debug.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Debug) {
            $crate::util::logging::emit($crate::util::logging::Level::Debug, format_args!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn level_from_str() {
        set_level_str("trace");
        assert!(enabled(Level::Trace));
        set_level_str("not-a-level"); // no-op
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }
}
