//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256PlusPlus` (Blackman & Vigna), the same
//! construction `rand_xoshiro` uses. Every stochastic component in the
//! simulator and workload generators takes an explicit seed so experiments
//! are bit-reproducible (`--seed` on every bench).

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style; n must be > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection-free 128-bit multiply method
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Exponential variate with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Zipf-like rank sample over [0, n) with exponent `s` (inverse-CDF on a
    /// precomputed table is overkill for our n ≤ 64 tenant counts; rejection
    /// sampling keeps it exact).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // normalizing constant
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(19);
        let mut counts = [0usize; 8];
        for _ in 0..50_000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[3] && counts[3] > counts[7]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
