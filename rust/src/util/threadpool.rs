//! Fixed-size worker pools over std threads + channels (no tokio offline).
//!
//! Two pools and a stage:
//!
//! * [`ThreadPool`] — stateless FIFO pool: submit closures, optionally
//!   collect results through `map`, shut down cleanly on drop.
//! * [`StatefulPool`] — per-worker owned state with targeted dispatch: the
//!   serving layer's multi-worker launch stage, where each worker owns a
//!   full model backend (PJRT client, compile caches, weights) built on
//!   its own thread, so the state type needs neither `Send` nor `Sync`.
//! * [`Stage`] — one dedicated, named, long-running pipeline-stage thread
//!   that hands a value back at shutdown: the serving layer's admission
//!   frontend worker (its thread-local metrics come home through `join`),
//!   and the socket intake's shard workers (per-shard intake counters).
//! * [`Notify`] — a monotonic eventcount over Mutex + Condvar: bounded
//!   waits that end *immediately* when a producer pulses, so an idle
//!   stage wakes on the first arrival instead of at its next poll tick.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed worker pool; jobs run FIFO across workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        // lint: LINT004 pool job queue; depth bounded by callers' wait_idle
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("vliw-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool rx poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                inflight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        // lint: LINT004 completion pulses; exactly one unit per item
        let (done_tx, done_rx) = mpsc::channel::<()>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let done = done_tx.clone();
            self.submit(move || {
                let r = f(item);
                results.lock().expect("results poisoned")[i] = Some(r);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker died");
        }
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .expect("results poisoned")
            .into_iter()
            .map(|o| o.expect("slot filled"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

type StateJob<S> = Box<dyn FnOnce(&mut S) + Send + 'static>;

/// Worker pool with per-worker owned state and targeted dispatch.
///
/// Worker `i` owns the state built by `init(i)` **on its own thread**, so
/// `S` needs neither `Send` nor `Sync` — one model backend per worker,
/// never crossing threads. Jobs are routed to a chosen worker: the serving
/// layer keys by model, so independent superkernels for different models
/// execute in parallel while one model's launches stay serialized (and
/// cache-warm) on their owner.
pub struct StatefulPool<S> {
    txs: Vec<mpsc::Sender<StateJob<S>>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    /// Per-worker submitted-but-unfinished counts — the placement layer's
    /// load signal for least-loaded replica routing.
    per_worker: Vec<Arc<AtomicUsize>>,
}

impl<S: 'static> StatefulPool<S> {
    /// Spawn `n` workers (n >= 1); worker `i` runs `init(i)` before its
    /// job loop.
    pub fn new<F>(n: usize, init: F) -> Self
    where
        F: Fn(usize) -> S + Send + Sync + 'static,
    {
        let n = n.max(1);
        let init = Arc::new(init);
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut txs = Vec::with_capacity(n);
        let mut per_worker = Vec::with_capacity(n);
        let workers = (0..n)
            .map(|i| {
                // lint: LINT004 per-worker job queue; bounded by wait_idle
                let (tx, rx) = mpsc::channel::<StateJob<S>>();
                txs.push(tx);
                let mine = Arc::new(AtomicUsize::new(0));
                per_worker.push(Arc::clone(&mine));
                let init = Arc::clone(&init);
                let inflight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("vliw-launch-{i}"))
                    .spawn(move || {
                        let mut state = init(i);
                        while let Ok(job) = rx.recv() {
                            job(&mut state);
                            mine.fetch_sub(1, Ordering::Release);
                            inflight.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn launch worker")
            })
            .collect();
        Self {
            txs,
            workers,
            in_flight,
            per_worker,
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Submit a job to worker `worker % n` (the caller's affinity key).
    pub fn submit_to<F>(&self, worker: usize, f: F)
    where
        F: FnOnce(&mut S) + Send + 'static,
    {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        let w = worker % self.txs.len();
        self.per_worker[w].fetch_add(1, Ordering::Acquire);
        self.txs[w].send(Box::new(f)).expect("worker alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Jobs submitted to one worker but not yet finished (queued +
    /// running) — the launch stage's per-device load signal.
    pub fn in_flight_of(&self, worker: usize) -> usize {
        self.per_worker[worker % self.per_worker.len()].load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }
}

impl<S> Drop for StatefulPool<S> {
    fn drop(&mut self) {
        self.txs.clear(); // closes channels; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A dedicated, named pipeline-stage thread that returns a value when it
/// finishes. Unlike the pools there is no job channel: the stage runs one
/// long-lived loop (the closure owns its receivers) and exits when its
/// input side disconnects. [`Stage::join`] blocks until then and hands
/// back whatever the closure accumulated (e.g. the admission frontend's
/// thread-local drop counts and latency histogram).
pub struct Stage<T> {
    handle: JoinHandle<T>,
}

impl<T: Send + 'static> Stage<T> {
    /// Spawn the stage thread under `name`.
    pub fn spawn<F>(name: &str, f: F) -> Self
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn stage");
        Stage { handle }
    }

    /// Wait for the stage to finish and take its result.
    pub fn join(self) -> T {
        self.handle.join().expect("stage panicked")
    }
}

/// A monotonic eventcount: producers `notify()`, consumers snapshot
/// `epoch()` before checking their work source and then `wait_past(seen)`
/// a bounded time. A pulse that lands between the snapshot and the wait is
/// never lost — the epoch has already advanced past `seen`, so the wait
/// returns immediately. This is the wake path between the socket intake
/// shards and anything polling them (new-connection handoff, stop
/// signals): the idle side sleeps a bounded interval but wakes the moment
/// a producer has something, so first-arrival latency after an idle
/// period is not floored by the poll interval.
#[derive(Default)]
pub struct Notify {
    seq: Mutex<u64>,
    cv: Condvar,
}

impl Notify {
    /// New eventcount at epoch 0.
    pub fn new() -> Self {
        Notify::default()
    }

    /// Current epoch. Snapshot this *before* checking the work source.
    pub fn epoch(&self) -> u64 {
        *self.seq.lock().expect("notify poisoned")
    }

    /// Advance the epoch and wake every waiter.
    pub fn notify(&self) {
        let mut seq = self.seq.lock().expect("notify poisoned");
        *seq += 1;
        self.cv.notify_all();
    }

    /// Block until the epoch moves past `seen` or `timeout` elapses.
    /// Returns true if woken by a pulse, false on timeout.
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> bool {
        let mut seq = self.seq.lock().expect("notify poisoned");
        let deadline = std::time::Instant::now() + timeout;
        while *seq <= seen {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                return false;
            }
            let (guard, res) = self
                .cv
                .wait_timeout(seq, left)
                .expect("notify poisoned");
            seq = guard;
            if res.timed_out() && *seq <= seen {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_worker_is_fifo() {
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..20 {
            let o = Arc::clone(&order);
            pool.submit(move || o.lock().unwrap().push(i));
        }
        pool.wait_idle();
        assert_eq!(*order.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn stateful_pool_state_needs_no_send() {
        // Rc is !Send: the state lives entirely on its worker thread
        use std::rc::Rc;
        let pool = StatefulPool::new(3, |i| Rc::new(i as u64 * 100));
        let (tx, rx) = mpsc::channel::<u64>();
        for w in 0..3usize {
            for j in 0..5u64 {
                let tx = tx.clone();
                pool.submit_to(w, move |s: &mut Rc<u64>| {
                    tx.send(**s + j).unwrap();
                });
            }
        }
        pool.wait_idle();
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        let mut want: Vec<u64> = (0..3u64)
            .flat_map(|w| (0..5).map(move |j| w * 100 + j))
            .collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn stateful_pool_serializes_per_worker() {
        // all jobs routed to one worker run FIFO against its state
        let pool = StatefulPool::new(2, |_| Vec::<u64>::new());
        let (tx, rx) = mpsc::channel::<Vec<u64>>();
        for i in 0..10u64 {
            pool.submit_to(0, move |s: &mut Vec<u64>| s.push(i));
        }
        pool.submit_to(0, move |s: &mut Vec<u64>| tx.send(s.clone()).unwrap());
        pool.wait_idle();
        assert_eq!(rx.recv().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn stateful_pool_tracks_per_worker_load() {
        let pool = StatefulPool::new(2, |_| ());
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        for _ in 0..3 {
            let g = Arc::clone(&gate);
            pool.submit_to(1, move |_| {
                let _ = g.lock().unwrap();
            });
        }
        // worker 1 holds 3 jobs (1 blocked on the gate + 2 queued), worker
        // 0 none — the routing signal the placement table consumes
        assert_eq!(pool.in_flight_of(1), 3);
        assert_eq!(pool.in_flight_of(0), 0);
        assert_eq!(pool.in_flight(), 3);
        drop(held);
        pool.wait_idle();
        assert_eq!(pool.in_flight_of(1), 0);
    }

    #[test]
    fn stage_returns_its_accumulated_value() {
        let (tx, rx) = mpsc::channel::<u64>();
        let stage = Stage::spawn("test-stage", move || {
            let mut sum = 0u64;
            while let Ok(x) = rx.recv() {
                sum += x;
            }
            sum // input disconnected: hand the accumulation back
        });
        for i in 1..=4 {
            tx.send(i).unwrap();
        }
        drop(tx);
        assert_eq!(stage.join(), 10);
    }

    #[test]
    fn notify_wakes_bounded_waiter_promptly() {
        let n = Arc::new(Notify::new());
        let n2 = Arc::clone(&n);
        let seen = n.epoch();
        let waiter = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let woken = n2.wait_past(seen, Duration::from_millis(500));
            (woken, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(10));
        n.notify();
        let (woken, waited) = waiter.join().unwrap();
        assert!(woken);
        // woke on the pulse, not at the 500ms poll ceiling
        assert!(waited < Duration::from_millis(400), "{waited:?}");
    }

    #[test]
    fn notify_pulse_before_wait_is_not_lost() {
        let n = Notify::new();
        let seen = n.epoch();
        n.notify(); // pulse lands before the wait starts
        assert!(n.wait_past(seen, Duration::from_millis(1)));
    }

    #[test]
    fn notify_times_out_without_pulse() {
        let n = Notify::new();
        let seen = n.epoch();
        assert!(!n.wait_past(seen, Duration::from_millis(5)));
    }

    #[test]
    fn stateful_pool_drop_joins_cleanly() {
        let pool = StatefulPool::new(2, |_| 0u64);
        pool.submit_to(1, |s| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            *s += 1;
        });
        drop(pool); // must not hang or panic
    }
}
