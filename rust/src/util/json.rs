//! Minimal JSON: value model, recursive-descent parser, compact writer.
//!
//! Used for the artifact manifest (`runtime::artifact`) and machine-readable
//! bench output. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII manifests; unpaired surrogates
//! are rejected).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// all JSON numbers (f64, like JS)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (BTreeMap for deterministic output ordering)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing garbage at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view (rejects non-integral numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Required-field helpers for manifest parsing (error with field name).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a string")))?
            .to_string())
    }

    /// Required u64 field.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a u64")))
    }

    /// Required f64 field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a number")))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build a Json object from pairs (bench-output convenience).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| Error::Json("surrogate \\u escape".into()))?,
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..ch_len)
                        .ok_or_else(|| Error::Json("truncated utf8".into()))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| Error::Json("bad utf8".into()))?,
                    );
                    self.i += ch_len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{txt}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\nb\t\"q\" é é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,-3],"s":"x\"y","t":true,"z":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn req_helpers_error_messages() {
        let j = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(j.req_u64("n").unwrap(), 3);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert!(j.req_u64("missing").is_err());
        assert!(j.req_str("n").is_err());
    }

    #[test]
    fn u64_rejects_fractional() {
        let j = Json::parse("3.5").unwrap();
        assert_eq!(j.as_u64(), None);
        assert_eq!(j.as_f64(), Some(3.5));
    }

    #[test]
    fn parses_real_manifest() {
        // smoke against the actual generated manifest when present
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.req_u64("version").unwrap(), 1);
            assert!(!j.get("models").unwrap().as_arr().unwrap().is_empty());
        }
    }
}
