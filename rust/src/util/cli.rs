//! Declarative flag parser (no `clap` offline). Supports `--flag value`,
//! `--flag=value`, boolean switches, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// One declared flag.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_switch: bool,
}

/// Declarative CLI argument parser.
///
/// ```no_run
/// # use vliw_jit::util::cli::Args;
/// let mut args = Args::new("demo", "demo tool");
/// args.flag("seed", "42", "rng seed");
/// args.switch("verbose", "log more");
/// let parsed = args.parse_from(vec!["--seed=7".into(), "--verbose".into()]).unwrap();
/// assert_eq!(parsed.get_u64("seed").unwrap(), 7);
/// assert!(parsed.get_bool("verbose"));
/// ```
#[derive(Debug, Clone)]
pub struct Args {
    prog: String,
    about: String,
    specs: Vec<FlagSpec>,
}

/// Parsed flag values.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// positional (non-flag) arguments, in order
    pub positional: Vec<String>,
}

impl Args {
    /// New parser for a program.
    pub fn new(prog: &str, about: &str) -> Self {
        Self {
            prog: prog.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
        }
    }

    /// Declare a valued flag with a default.
    pub fn flag(&mut self, name: &str, default: &str, help: &str) -> &mut Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_switch: false,
        });
        self
    }

    /// Declare a boolean switch (default false).
    pub fn switch(&mut self, name: &str, help: &str) -> &mut Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_switch: true,
        });
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.prog, self.about);
        for f in &self.specs {
            let d = f
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", f.name, f.help, d));
        }
        s.push_str("  --help                   print this help\n");
        s
    }

    /// Parse `std::env::args()` (exits on --help).
    pub fn parse(&self) -> Result<Parsed> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            print!("{}", self.help());
            std::process::exit(0);
        }
        self.parse_from(argv)
    }

    /// Parse an explicit argv (testable).
    pub fn parse_from(&self, argv: Vec<String>) -> Result<Parsed> {
        let mut p = Parsed {
            values: BTreeMap::new(),
            switches: BTreeMap::new(),
            positional: Vec::new(),
        };
        for f in &self.specs {
            if let Some(d) = &f.default {
                p.values.insert(f.name.clone(), d.clone());
            }
            if f.is_switch {
                p.switches.insert(f.name.clone(), false);
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| Error::config(format!("unknown flag --{name}")))?;
                if spec.is_switch {
                    if inline.is_some() {
                        return Err(Error::config(format!("switch --{name} takes no value")));
                    }
                    p.switches.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| Error::config(format!("--{name} needs a value")))?,
                    };
                    p.values.insert(name, v);
                }
            } else {
                p.positional.push(a);
            }
        }
        Ok(p)
    }
}

impl Parsed {
    /// String flag value.
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag '{name}' not declared"))
    }

    /// u64 flag value.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be a u64, got '{}'", self.get(name))))
    }

    /// usize flag value.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get_u64(name)? as usize)
    }

    /// f64 flag value.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name).parse().map_err(|_| {
            Error::config(format!("--{name} must be a number, got '{}'", self.get(name)))
        })
    }

    /// Switch state.
    pub fn get_bool(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch '{name}' not declared"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            Vec::new()
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }

    /// Comma-separated list flag that must hold at least one non-empty
    /// item (`--devices ,,` or `--devices ""` is a config error, not an
    /// empty fleet).
    pub fn get_nonempty_list(&self, name: &str) -> Result<Vec<String>> {
        let items: Vec<String> = self
            .get_list(name)
            .into_iter()
            .filter(|s| !s.is_empty())
            .collect();
        if items.is_empty() {
            Err(Error::config(format!("--{name} needs at least one item")))
        } else {
            Ok(items)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        let mut a = Args::new("t", "test");
        a.flag("seed", "42", "rng seed")
            .flag("models", "", "model list")
            .flag("rate", "1.5", "req/s")
            .switch("verbose", "chatty");
        a
    }

    #[test]
    fn defaults_apply() {
        let p = args().parse_from(vec![]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 42);
        assert!((p.get_f64("rate").unwrap() - 1.5).abs() < 1e-12);
        assert!(!p.get_bool("verbose"));
        assert!(p.get_list("models").is_empty());
    }

    #[test]
    fn equals_and_space_forms() {
        let p = args()
            .parse_from(vec!["--seed=7".into(), "--rate".into(), "2.0".into()])
            .unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 7);
        assert_eq!(p.get_f64("rate").unwrap(), 2.0);
    }

    #[test]
    fn switches_and_positional() {
        let p = args()
            .parse_from(vec!["pos1".into(), "--verbose".into(), "pos2".into()])
            .unwrap();
        assert!(p.get_bool("verbose"));
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn list_parsing() {
        let p = args()
            .parse_from(vec!["--models=a, b,c".into()])
            .unwrap();
        assert_eq!(p.get_list("models"), vec!["a", "b", "c"]);
    }

    #[test]
    fn nonempty_list_rejects_blank() {
        let p = args().parse_from(vec!["--models=a,,b".into()]).unwrap();
        assert_eq!(p.get_nonempty_list("models").unwrap(), vec!["a", "b"]);
        let empty = args().parse_from(vec![]).unwrap();
        assert!(empty.get_nonempty_list("models").is_err());
        let blank = args().parse_from(vec!["--models=,".into()]).unwrap();
        assert!(blank.get_nonempty_list("models").is_err());
    }

    #[test]
    fn errors() {
        assert!(args().parse_from(vec!["--nope".into()]).is_err());
        assert!(args().parse_from(vec!["--seed".into()]).is_err());
        assert!(args().parse_from(vec!["--verbose=1".into()]).is_err());
        let p = args().parse_from(vec!["--seed=abc".into()]).unwrap();
        assert!(p.get_u64("seed").is_err());
    }

    #[test]
    fn help_mentions_flags() {
        let h = args().help();
        assert!(h.contains("--seed") && h.contains("default: 42"));
    }
}
