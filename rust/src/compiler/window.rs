//! The OoO issue window.
//!
//! Holds pending [`TensorOp`]s from all streams, tracks per-stream program
//! order (an op is *ready* once its predecessor in the same stream has
//! completed) and deadline bookkeeping. This is the VLIW analogy's
//! instruction window: the scheduler picks ready ops out of order, the
//! coalescer packs them into long words.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::compiler::ir::{DispatchRequest, OpId, StreamId, TensorOp};

/// Issue-window state for one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    /// Waiting on an earlier op of the same stream.
    Blocked,
    /// Eligible for issue.
    Ready,
    /// Issued to the executor, not yet complete.
    InFlight,
}

/// The out-of-order issue window.
#[derive(Debug, Default)]
pub struct Window {
    ops: HashMap<OpId, (TensorOp, OpState)>,
    /// per-stream queue of pending op ids in program order
    streams: BTreeMap<StreamId, VecDeque<OpId>>,
    /// per-stream next sequence number
    next_seq: HashMap<StreamId, u64>,
    /// per-stream in-flight count (head-of-line dependency tracking)
    inflight: HashMap<StreamId, usize>,
    next_id: u64,
    capacity: usize,
}

impl Window {
    /// Window with a capacity bound (admission control backstop).
    pub fn new(capacity: usize) -> Self {
        Window {
            capacity,
            ..Default::default()
        }
    }

    /// Number of pending + in-flight ops.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if no ops are pending or in flight.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// True if at capacity (submit would fail).
    pub fn is_full(&self) -> bool {
        self.ops.len() >= self.capacity
    }

    /// Submit a dispatch request at time `now`. Returns the assigned op id,
    /// or `None` when the window is full (caller applies backpressure).
    pub fn submit(&mut self, req: DispatchRequest, now: f64) -> Option<OpId> {
        if self.is_full() {
            return None;
        }
        let id = OpId(self.next_id);
        self.next_id += 1;
        let seq_ref = self.next_seq.entry(req.stream).or_insert(0);
        let seq = *seq_ref;
        *seq_ref += 1;
        let op = TensorOp {
            id,
            stream: req.stream,
            seq,
            kernel: req.kernel,
            arrival_us: now,
            deadline_us: now + req.slo_us,
            tag: req.tag,
        };
        let q = self.streams.entry(req.stream).or_default();
        // ready iff nothing earlier from this stream is pending or in flight
        let state = if q.is_empty() && self.inflight.get(&req.stream).copied().unwrap_or(0) == 0
        {
            OpState::Ready
        } else {
            OpState::Blocked
        };
        q.push_back(id);
        self.ops.insert(id, (op, state));
        Some(id)
    }

    /// All currently ready ops (unordered; scheduler imposes policy order).
    pub fn ready(&self) -> Vec<&TensorOp> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op)
            .collect()
    }

    /// Number of ready ops.
    pub fn ready_count(&self) -> usize {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .count()
    }

    /// Look up an op.
    pub fn get(&self, id: OpId) -> Option<&TensorOp> {
        self.ops.get(&id).map(|(op, _)| op)
    }

    /// State of an op.
    pub fn state(&self, id: OpId) -> Option<OpState> {
        self.ops.get(&id).map(|(_, s)| *s)
    }

    /// Mark ops as issued (Ready → InFlight). Panics if any op is not ready
    /// — the scheduler must never issue blocked ops.
    pub fn issue(&mut self, ids: &[OpId]) {
        for id in ids {
            let (op, state) = self.ops.get_mut(id).expect("issue of unknown op");
            assert_eq!(
                *state,
                OpState::Ready,
                "scheduler issued non-ready op {id:?}"
            );
            *state = OpState::InFlight;
            *self.inflight.entry(op.stream).or_insert(0) += 1;
            // pop from the stream queue head (must be the head by program
            // order; ready implies it is)
            let q = self.streams.get_mut(&op.stream).expect("stream queue");
            let head = q.pop_front().expect("queue non-empty");
            assert_eq!(head, *id, "program order violated on issue");
        }
    }

    /// Complete an in-flight op, unblocking its stream successor. Returns
    /// the completed op.
    pub fn complete(&mut self, id: OpId) -> TensorOp {
        let (op, state) = self.ops.remove(&id).expect("complete of unknown op");
        assert_eq!(state, OpState::InFlight, "complete of non-inflight op");
        let cnt = self.inflight.get_mut(&op.stream).expect("inflight count");
        *cnt -= 1;
        if *cnt == 0 {
            // head of this stream's queue (if any) becomes ready
            if let Some(q) = self.streams.get(&op.stream) {
                if let Some(&head) = q.front() {
                    if let Some((_, s)) = self.ops.get_mut(&head) {
                        *s = OpState::Ready;
                    }
                }
            }
        }
        op
    }

    /// Re-queue an evicted in-flight op (straggler eviction, §5.2): it goes
    /// back to the *front* of its stream as Ready with its original
    /// deadline, so the scheduler re-prioritizes it immediately.
    pub fn requeue(&mut self, id: OpId) {
        let (op, state) = self.ops.get_mut(&id).expect("requeue of unknown op");
        assert_eq!(*state, OpState::InFlight, "requeue of non-inflight op");
        *state = OpState::Ready;
        let cnt = self.inflight.get_mut(&op.stream).expect("inflight count");
        *cnt -= 1;
        let q = self.streams.entry(op.stream).or_default();
        q.push_front(id);
        // if something else of this stream is in flight, it must block
        if self.inflight.get(&op.stream).copied().unwrap_or(0) > 0 {
            let (_, s) = self.ops.get_mut(&id).unwrap();
            *s = OpState::Blocked;
        }
    }

    /// Earliest deadline among ready ops (scheduler's EDF pivot).
    pub fn earliest_deadline(&self) -> Option<f64> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op.deadline_us)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::kernel::KernelDesc;

    fn req(stream: u32) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(32, 256, 64), 10_000.0)
    }

    #[test]
    fn submit_assigns_program_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 1.0).unwrap();
        assert_eq!(w.get(a).unwrap().seq, 0);
        assert_eq!(w.get(b).unwrap().seq, 1);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
    }

    #[test]
    fn streams_are_independent() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(1), 0.0).unwrap();
        // different stream: immediately ready despite stream 0's pending op
        assert_eq!(w.state(b), Some(OpState::Ready));
        assert_eq!(w.ready_count(), 2);
    }

    #[test]
    fn complete_unblocks_successor() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        assert_eq!(w.state(b), Some(OpState::Blocked));
        w.complete(a);
        assert_eq!(w.state(b), Some(OpState::Ready));
        w.issue(&[b]);
        w.complete(b);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic(expected = "non-ready")]
    fn issuing_blocked_op_panics() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[b]);
    }

    #[test]
    fn capacity_backpressure() {
        let mut w = Window::new(2);
        assert!(w.submit(req(0), 0.0).is_some());
        assert!(w.submit(req(1), 0.0).is_some());
        assert!(w.submit(req(2), 0.0).is_none());
        assert!(w.is_full());
    }

    #[test]
    fn requeue_restores_readiness_and_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.requeue(a); // evicted straggler
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
        // must issue a before b again
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.state(b), Some(OpState::Ready));
    }

    #[test]
    fn earliest_deadline_tracks_ready_only() {
        let mut w = Window::new(16);
        let a = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 5_000.0),
                0.0,
            )
            .unwrap();
        let _b = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 1_000.0),
                0.0,
            )
            .unwrap();
        // b has the tighter deadline but is blocked behind a
        assert_eq!(w.earliest_deadline(), Some(5_000.0));
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.earliest_deadline(), Some(1_000.0));
    }
}
