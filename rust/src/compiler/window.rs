//! The OoO issue window.
//!
//! Holds pending [`TensorOp`]s from all streams, tracks per-stream program
//! order and deadline bookkeeping. This is the VLIW analogy's instruction
//! window: the scheduler picks ready ops out of order, the coalescer packs
//! them into long words.
//!
//! Readiness is *issue-order*, not completion-order: an op is ready once
//! every earlier op of its stream has been **issued**. Program order is
//! still enforced at issue time (a stream's ops enter the device in
//! sequence), but a stream may have several ops in flight at once — the
//! pipelining the concurrent launch stage needs. Deployments that require
//! a completion barrier between a stream's ops get it for free in the
//! synchronous drive mode, where every launch completes before the next
//! decision.
//!
//! **Independent ops relax this further.** An op submitted with
//! [`DispatchRequest::with_independent`] carries no data dependence on its
//! stream's earlier ops (the serving layer's stateless inference
//! requests), so the window exposes a stream's contiguous ready **prefix**
//! rather than just its head: the queue front is always ready, and
//! independent ops directly behind it are ready too, until the first
//! dependent op blocks itself and everything after it. A whole burst from
//! one (tenant, model) stream can therefore ride a single superkernel
//! launch instead of serializing into singleton packs. Independent ops may
//! also issue out of prefix order (e.g. when shape classes split a prefix
//! across packs); dependent ops keep strict per-stream issue order.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::compiler::ir::{DispatchRequest, OpId, StreamId, TensorOp};

/// Issue-window state for one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    /// Waiting on an earlier op of the same stream to issue (or, for a
    /// queued op behind a dependent one, on the prefix ahead of it).
    Blocked,
    /// Eligible for issue.
    Ready,
    /// Issued to the executor, not yet complete.
    InFlight,
}

/// A ready-set membership change, recorded by every mutation that flips an
/// op into or out of `Ready`. The incremental scheduler drains these
/// through [`Window::take_ready_deltas`] to keep its bucket mirror in sync
/// without rescanning the window. Deltas carry only the op id: ids are
/// never reused and an op's bucket-relevant fields (group, class, shape,
/// deadline) are immutable, so the scheduler resolves an `Enter` against
/// the live window at drain time (an op that already left again resolves
/// to a later `Leave` in the same log).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadyDelta {
    /// The op became Ready (admitted ready, unblocked by an issue, or
    /// promoted after a requeue).
    Enter(OpId),
    /// The op stopped being Ready (issued, or demoted behind a requeued
    /// dependent op).
    Leave(OpId),
}

/// Bound on the un-drained delta log. A window whose consumer never drains
/// (naive decide paths, admission-only use) stops recording at this depth
/// and flags overflow; the next drain then reports "resync required"
/// instead of handing out a truncated log.
const DELTA_LOG_CAP: usize = 8192;

/// Process-global window identity counter — see [`Window::stamp`].
static WINDOW_STAMP: AtomicU64 = AtomicU64::new(1);

/// The out-of-order issue window.
#[derive(Debug)]
pub struct Window {
    ops: HashMap<OpId, (TensorOp, OpState)>,
    /// per-stream queue of pending (un-issued) op ids in program order
    streams: BTreeMap<StreamId, VecDeque<OpId>>,
    /// per-stream next sequence number
    next_seq: HashMap<StreamId, u64>,
    /// per-stream in-flight count (several ops of one stream may be in
    /// flight at once under the concurrent launch stage)
    inflight: HashMap<StreamId, usize>,
    /// per-group pending (un-issued) op count — the admission layer's
    /// queue-depth signal
    group_pending: HashMap<u64, usize>,
    /// per-group in-flight op count — launches already on the device still
    /// drain ahead of a newly admitted request (admission pricing)
    group_inflight: HashMap<u64, usize>,
    next_id: u64,
    capacity: usize,
    /// unique per-window identity (see [`Window::stamp`])
    stamp: u64,
    /// ready-set changes since the last [`Window::take_ready_deltas`]
    deltas: Vec<ReadyDelta>,
    /// true once `deltas` hit [`DELTA_LOG_CAP`] and stopped recording
    delta_overflow: bool,
}

impl Default for Window {
    fn default() -> Self {
        Window {
            ops: HashMap::new(),
            streams: BTreeMap::new(),
            next_seq: HashMap::new(),
            inflight: HashMap::new(),
            group_pending: HashMap::new(),
            group_inflight: HashMap::new(),
            next_id: 0,
            capacity: 0,
            stamp: WINDOW_STAMP.fetch_add(1, Ordering::Relaxed),
            deltas: Vec::new(),
            delta_overflow: false,
        }
    }
}

impl Window {
    /// Window with a capacity bound (admission control backstop).
    pub fn new(capacity: usize) -> Self {
        Window {
            capacity,
            ..Default::default()
        }
    }

    /// Number of pending + in-flight ops.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if no ops are pending or in flight.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// True if at capacity (submit would fail).
    pub fn is_full(&self) -> bool {
        self.ops.len() >= self.capacity
    }

    /// Pending (un-issued) ops in a coalescing group — the serving layer's
    /// per-model queue depth.
    pub fn pending_in_group(&self, group: u64) -> usize {
        self.group_pending.get(&group).copied().unwrap_or(0)
    }

    /// In-flight (issued, not yet complete) ops in a coalescing group.
    /// Admission must price these too: under the pooled/async drive mode a
    /// new request drains behind the launches already on the device, not
    /// just behind the un-issued queue.
    pub fn inflight_in_group(&self, group: u64) -> usize {
        self.group_inflight.get(&group).copied().unwrap_or(0)
    }

    /// Longest per-stream pending run within a group. When program order
    /// binds (no independence flag), each launch takes at most one op per
    /// stream, so this — not the total group depth — bounds the number of
    /// launches a drain needs (admission's dependent-mode pricing).
    /// O(pending ops) per call; fine for admission-rate queries.
    pub fn max_stream_depth_in_group(&self, group: u64) -> usize {
        self.streams
            .values()
            .map(|q| {
                q.iter()
                    .filter(|id| self.ops[*id].0.group == group)
                    .count()
            })
            .max()
            .unwrap_or(0)
    }

    /// Pending ops of one stream within a group (that stream's own queue
    /// run — the companion to [`Window::max_stream_depth_in_group`]).
    pub fn stream_depth_in_group(&self, stream: StreamId, group: u64) -> usize {
        self.streams
            .get(&stream)
            .map(|q| {
                q.iter()
                    .filter(|id| self.ops[*id].0.group == group)
                    .count()
            })
            .unwrap_or(0)
    }

    /// Pending depth of every stream holding un-issued ops in `group` —
    /// the admission snapshot's dependent-mode pricing input (the
    /// per-stream companion to [`Window::max_stream_depth_in_group`],
    /// whose value is this list's max). O(pending ops) per call, same as
    /// the max variant; called per snapshot publication on the frontend
    /// path and per admission on the synchronous one (which previously
    /// paid the same two O(pending) scans inline).
    pub fn stream_depths_in_group(&self, group: u64) -> Vec<(StreamId, usize)> {
        self.streams
            .iter()
            .filter_map(|(s, q)| {
                let d = q.iter().filter(|id| self.ops[*id].0.group == group).count();
                (d > 0).then_some((*s, d))
            })
            .collect()
    }

    /// Streams with live bookkeeping (pending queue, seq counter, or
    /// in-flight counter). Bounded by the set of streams with work in the
    /// window — the regression surface for the tenant-churn leak fix.
    pub fn tracked_streams(&self) -> usize {
        self.streams
            .len()
            .max(self.next_seq.len())
            .max(self.inflight.len())
    }

    /// Groups with live bookkeeping (pending or in-flight counters).
    pub fn tracked_groups(&self) -> usize {
        self.group_pending.len().max(self.group_inflight.len())
    }

    /// Unique identity of this window instance (process-global counter,
    /// assigned at construction). The incremental scheduler keys its
    /// persistent bucket mirror on this: a scheduler pointed at a window
    /// it has never drained (or a different window than last time) must
    /// resync from scratch rather than trust its cache.
    pub fn stamp(&self) -> u64 {
        self.stamp
    }

    /// Drain the ready-set delta log into `out` (cleared first; the
    /// allocation is swapped, not copied, so a reused `out` makes the
    /// steady state allocation-free). Returns `true` when the log
    /// overflowed since the last drain — the content of `out` is then
    /// incomplete and the caller must resync from [`Window::ready`].
    pub fn take_ready_deltas(&mut self, out: &mut Vec<ReadyDelta>) -> bool {
        out.clear();
        std::mem::swap(&mut self.deltas, out);
        let overflow = self.delta_overflow;
        self.delta_overflow = false;
        overflow
    }

    /// Submit a dispatch request at time `now`. Returns the assigned op id,
    /// or `None` when the window is full (caller applies backpressure).
    pub fn submit(&mut self, req: DispatchRequest, now: f64) -> Option<OpId> {
        if self.is_full() {
            return None;
        }
        let id = OpId(self.next_id);
        self.next_id += 1;
        let seq_ref = self.next_seq.entry(req.stream).or_insert(0);
        let seq = *seq_ref;
        *seq_ref += 1;
        let op = TensorOp {
            id,
            stream: req.stream,
            seq,
            kernel: req.kernel,
            arrival_us: now,
            deadline_us: now + req.slo_us,
            group: req.group,
            tag: req.tag,
            independent: req.independent,
            class: req.class,
        };
        let q = self.streams.entry(req.stream).or_default();
        // ready iff nothing earlier from this stream awaits issue, or the
        // op is independent and joins a fully-ready prefix (the previous
        // queue tail being ready implies every queued predecessor is)
        let state = match q.back() {
            None => OpState::Ready,
            Some(prev)
                if req.independent
                    && matches!(self.ops.get(prev), Some((_, OpState::Ready))) =>
            {
                OpState::Ready
            }
            _ => OpState::Blocked,
        };
        q.push_back(id);
        *self.group_pending.entry(req.group).or_insert(0) += 1;
        self.ops.insert(id, (op, state));
        if state == OpState::Ready {
            if self.deltas.len() < DELTA_LOG_CAP {
                self.deltas.push(ReadyDelta::Enter(id));
            } else {
                self.delta_overflow = true;
            }
        }
        Some(id)
    }

    /// All currently ready ops (unordered; scheduler imposes policy order).
    pub fn ready(&self) -> Vec<&TensorOp> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op)
            .collect()
    }

    /// Number of ready ops.
    pub fn ready_count(&self) -> usize {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .count()
    }

    /// Look up an op.
    pub fn get(&self, id: OpId) -> Option<&TensorOp> {
        self.ops.get(&id).map(|(op, _)| op)
    }

    /// State of an op.
    pub fn state(&self, id: OpId) -> Option<OpState> {
        self.ops.get(&id).map(|(_, s)| *s)
    }

    /// Pending (un-issued) same-stream ops with a lower sequence number
    /// than `id` — the predecessors program order requires to issue
    /// first. Empty for an unknown op. The plan verifier
    /// ([`crate::analysis::plan`]) checks this is empty for every
    /// dependent op in a pack (PLAN001); correct window bookkeeping
    /// guarantees it, so a non-empty answer for a Ready dependent op
    /// means the ready-prefix state machine regressed.
    pub fn pending_predecessors(&self, id: OpId) -> Vec<OpId> {
        let Some((op, _)) = self.ops.get(&id) else {
            return Vec::new();
        };
        self.streams
            .get(&op.stream)
            .map(|q| {
                q.iter()
                    .filter(|x| **x != id && self.ops[*x].0.seq < op.seq)
                    .copied()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Mark ops as issued (Ready → InFlight), unblocking each stream's
    /// successor prefix. Panics if any op is not ready — the scheduler must
    /// never issue blocked ops. Dependent ops leave from the queue front
    /// (program order); independent ops may leave from the middle of the
    /// ready prefix (e.g. when shape classes split a prefix across packs).
    pub fn issue(&mut self, ids: &[OpId]) {
        // streams touched by this pack; readiness is refreshed once per
        // stream after all removals (issuing only ever EXTENDS a prefix —
        // it never revokes another op's readiness — so deferring the
        // relabel is safe and keeps a k-op same-stream pack O(k + queue)
        // instead of O(k·queue))
        let mut touched: Vec<StreamId> = Vec::with_capacity(ids.len());
        for id in ids {
            let (op, state) = self.ops.get_mut(id).expect("issue of unknown op");
            assert_eq!(
                *state,
                OpState::Ready,
                "scheduler issued non-ready op {id:?}"
            );
            *state = OpState::InFlight;
            let (stream, group, independent) = (op.stream, op.group, op.independent);
            if self.deltas.len() < DELTA_LOG_CAP {
                self.deltas.push(ReadyDelta::Leave(*id));
            } else {
                self.delta_overflow = true;
            }
            *self.inflight.entry(stream).or_insert(0) += 1;
            *self.group_inflight.entry(group).or_insert(0) += 1;
            let pending = self
                .group_pending
                .get_mut(&group)
                .expect("group pending count");
            *pending -= 1;
            if *pending == 0 {
                self.group_pending.remove(&group);
            }
            let q = self.streams.get_mut(&stream).expect("stream queue");
            if q.front() == Some(id) {
                q.pop_front();
            } else {
                assert!(independent, "dependent op issued out of program order");
                let pos = q
                    .iter()
                    .position(|x| x == id)
                    .expect("issued op in its stream queue");
                let _ = q.remove(pos);
            }
            if !touched.contains(&stream) {
                touched.push(stream);
            }
        }
        // ops behind the issued ones may become ready: the new front
        // always is, and independents extend the prefix behind it
        for stream in touched {
            self.refresh_ready(stream);
        }
    }

    /// Recompute a stream's ready prefix: the queue front is ready (all of
    /// its predecessors issued), and ops behind it stay ready only while
    /// every one of them is independent — the first dependent op blocks
    /// itself and everything after it (contiguous-prefix readiness).
    ///
    /// Cost is O(ready prefix), not O(queue): every public mutation leaves
    /// the queue Ready-prefix-then-Blocked-suffix EXCEPT a `requeue` that
    /// just inserted one Blocked op mid-queue — so while relabeling past
    /// the prefix, a single already-Blocked op may still be followed by
    /// stale Ready ops needing demotion, but TWO consecutive already-
    /// Blocked ops mean the walk has reached the settled suffix and may
    /// stop (by induction, the shape held before the one-op insert). A
    /// deep dependent-only backlog therefore pays O(1) per issue.
    fn refresh_ready(&mut self, stream: StreamId) {
        let Some(q) = self.streams.get(&stream) else {
            return;
        };
        let mut ready = true;
        let mut prev_already_blocked = false;
        for (i, id) in q.iter().enumerate() {
            let (op, state) = self.ops.get_mut(id).expect("queued op in window");
            debug_assert_ne!(*state, OpState::InFlight, "queued op cannot be in flight");
            ready = ready && (i == 0 || op.independent);
            if ready {
                if *state != OpState::Ready {
                    *state = OpState::Ready;
                    if self.deltas.len() < DELTA_LOG_CAP {
                        self.deltas.push(ReadyDelta::Enter(*id));
                    } else {
                        self.delta_overflow = true;
                    }
                }
                prev_already_blocked = false;
            } else {
                let already_blocked = *state == OpState::Blocked;
                if already_blocked && prev_already_blocked {
                    break; // settled Blocked suffix (see above)
                }
                if !already_blocked {
                    // demotion of a (necessarily Ready) op — the InFlight
                    // case is excluded by the debug_assert above
                    *state = OpState::Blocked;
                    if self.deltas.len() < DELTA_LOG_CAP {
                        self.deltas.push(ReadyDelta::Leave(*id));
                    } else {
                        self.delta_overflow = true;
                    }
                }
                prev_already_blocked = already_blocked;
            }
        }
    }

    /// Complete an in-flight op. Returns the completed op. Bookkeeping for
    /// fully-drained streams and groups is dropped here — a long-running
    /// server sees tenants come and go, and retaining every (tenant, model)
    /// queue/seq/counter entry forever is an unbounded leak. A stream that
    /// later returns restarts at seq 0 against an empty queue, which still
    /// preserves program order (nothing of its old life remains pending).
    pub fn complete(&mut self, id: OpId) -> TensorOp {
        let (op, state) = self.ops.remove(&id).expect("complete of unknown op");
        assert_eq!(state, OpState::InFlight, "complete of non-inflight op");
        let cnt = self.inflight.get_mut(&op.stream).expect("inflight count");
        *cnt -= 1;
        let stream_drained = *cnt == 0;
        if stream_drained {
            self.inflight.remove(&op.stream);
        }
        let gcnt = self
            .group_inflight
            .get_mut(&op.group)
            .expect("group inflight count");
        *gcnt -= 1;
        if *gcnt == 0 {
            self.group_inflight.remove(&op.group);
        }
        let queue_empty = match self.streams.get(&op.stream) {
            Some(q) => q.is_empty(),
            None => true,
        };
        if stream_drained && queue_empty {
            self.streams.remove(&op.stream);
            self.next_seq.remove(&op.stream);
        }
        op
    }

    /// Re-queue an evicted in-flight op (straggler eviction, §5.2): it
    /// re-enters its stream's pending queue *in program order* with its
    /// original deadline, so the scheduler re-prioritizes it immediately.
    /// In the common case (in-order issue) that is the queue front; an
    /// independent op that issued out of prefix order re-enters behind any
    /// still-pending lower-seq peers — the queue must stay sorted by seq,
    /// or a dependent op whose predecessors have all issued would be
    /// spuriously demoted behind the returning straggler. Dependent ops
    /// with higher seq block again; independents stay in the ready prefix.
    pub fn requeue(&mut self, id: OpId) {
        let (op, state) = self.ops.get_mut(&id).expect("requeue of unknown op");
        assert_eq!(*state, OpState::InFlight, "requeue of non-inflight op");
        // re-enter as Blocked and let refresh_ready promote it: pre-marking
        // Ready would go stale when the op lands behind a Blocked op (the
        // prefix walk stops at the first Blocked entry and would never
        // visit it), letting a dependent op schedule out of program order
        *state = OpState::Blocked;
        let (stream, group, seq) = (op.stream, op.group, op.seq);
        let cnt = self.inflight.get_mut(&stream).expect("inflight count");
        *cnt -= 1;
        if *cnt == 0 {
            self.inflight.remove(&stream);
        }
        let gcnt = self
            .group_inflight
            .get_mut(&group)
            .expect("group inflight count");
        *gcnt -= 1;
        if *gcnt == 0 {
            self.group_inflight.remove(&group);
        }
        *self.group_pending.entry(group).or_insert(0) += 1;
        let q = self.streams.entry(stream).or_default();
        let pos = q
            .iter()
            .position(|x| self.ops[x].0.seq > seq)
            .unwrap_or(q.len());
        q.insert(pos, id);
        self.refresh_ready(stream);
    }

    /// Earliest deadline among ready ops (scheduler's EDF pivot).
    pub fn earliest_deadline(&self) -> Option<f64> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op.deadline_us)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::kernel::KernelDesc;

    fn req(stream: u32) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(32, 256, 64), 10_000.0)
    }

    #[test]
    fn submit_carries_slo_class_onto_the_op() {
        use crate::compiler::ir::SloClass;
        let mut w = Window::new(16);
        let a = w.submit(req(0).with_class(SloClass::Critical), 0.0).unwrap();
        let b = w.submit(req(1), 0.0).unwrap();
        assert_eq!(w.get(a).unwrap().class, SloClass::Critical);
        assert_eq!(w.get(b).unwrap().class, SloClass::Standard, "default");
    }

    #[test]
    fn submit_assigns_program_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 1.0).unwrap();
        assert_eq!(w.get(a).unwrap().seq, 0);
        assert_eq!(w.get(b).unwrap().seq, 1);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
    }

    #[test]
    fn streams_are_independent() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(1), 0.0).unwrap();
        // different stream: immediately ready despite stream 0's pending op
        assert_eq!(w.state(b), Some(OpState::Ready));
        assert_eq!(w.ready_count(), 2);
    }

    #[test]
    fn stream_depths_in_group_counts_pending_only() {
        let mut w = Window::new(16);
        let g = |stream: u32| req(stream).with_group(7);
        let a = w.submit(g(0), 0.0).unwrap();
        let _b = w.submit(g(0).with_independent(true), 0.0).unwrap();
        let _c = w.submit(g(1), 0.0).unwrap();
        let mut d = w.stream_depths_in_group(7);
        d.sort();
        assert_eq!(d, vec![(StreamId(0), 2), (StreamId(1), 1)]);
        assert!(w.stream_depths_in_group(99).is_empty());
        // issue removes the op from its stream's pending run
        w.issue(&[a]);
        let mut d = w.stream_depths_in_group(7);
        d.sort();
        assert_eq!(d, vec![(StreamId(0), 1), (StreamId(1), 1)]);
        // consistency with the max variant
        assert_eq!(w.max_stream_depth_in_group(7), 1);
    }

    #[test]
    fn issue_unblocks_successor_for_pipelining() {
        // issue-order readiness: b becomes ready as soon as a is issued,
        // so one stream can keep several ops in flight
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        assert_eq!(w.state(b), Some(OpState::Ready));
        w.issue(&[b]);
        assert_eq!(w.state(a), Some(OpState::InFlight));
        assert_eq!(w.state(b), Some(OpState::InFlight));
        w.complete(a);
        w.complete(b);
        assert!(w.is_empty());
    }

    #[test]
    fn completion_order_free_within_stream() {
        // two in-flight ops of one stream may complete out of order
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.issue(&[b]);
        w.complete(b);
        w.complete(a);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic(expected = "non-ready")]
    fn issuing_blocked_op_panics() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[b]);
    }

    #[test]
    fn capacity_backpressure() {
        let mut w = Window::new(2);
        assert!(w.submit(req(0), 0.0).is_some());
        assert!(w.submit(req(1), 0.0).is_some());
        assert!(w.submit(req(2), 0.0).is_none());
        assert!(w.is_full());
    }

    #[test]
    fn requeue_restores_readiness_and_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.requeue(a); // evicted straggler
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
        // must issue a before b again
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.state(b), Some(OpState::Ready));
    }

    #[test]
    fn requeue_with_multiple_inflight_ops_per_stream() {
        // a and b both in flight; a straggles and is evicted: it must come
        // back at the *front* of the stream, ahead of pending c, while b
        // stays in flight and can still complete
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        let c = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.issue(&[b]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.requeue(a);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(c), Some(OpState::Blocked), "a re-enters ahead of c");
        assert_eq!(w.state(b), Some(OpState::InFlight));
        w.complete(b); // out-of-order completion is fine
        w.issue(&[a]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.complete(a);
        w.issue(&[c]);
        w.complete(c);
        assert!(w.is_empty());
    }

    #[test]
    fn max_stream_depth_in_group_tracks_longest_pending_run() {
        let mut w = Window::new(16);
        w.submit(req(0).with_group(7), 0.0).unwrap();
        w.submit(req(0).with_group(7), 0.0).unwrap();
        w.submit(req(1).with_group(7), 0.0).unwrap();
        let a = w.submit(req(2).with_group(9), 0.0).unwrap();
        assert_eq!(w.max_stream_depth_in_group(7), 2, "stream 0's run of 2");
        assert_eq!(w.max_stream_depth_in_group(9), 1);
        assert_eq!(w.max_stream_depth_in_group(42), 0);
        assert_eq!(w.stream_depth_in_group(StreamId(0), 7), 2);
        assert_eq!(w.stream_depth_in_group(StreamId(1), 7), 1);
        assert_eq!(w.stream_depth_in_group(StreamId(1), 9), 0);
        assert_eq!(w.stream_depth_in_group(StreamId(99), 7), 0, "unknown stream");
        w.issue(&[a]);
        assert_eq!(
            w.max_stream_depth_in_group(9),
            0,
            "in-flight ops are not pending"
        );
    }

    #[test]
    fn group_pending_tracks_unissued_ops() {
        let mut w = Window::new(16);
        let a = w
            .submit(req(0).with_group(7), 0.0)
            .unwrap();
        let _b = w.submit(req(1).with_group(7), 0.0).unwrap();
        let _c = w.submit(req(2).with_group(9), 0.0).unwrap();
        assert_eq!(w.pending_in_group(7), 2);
        assert_eq!(w.pending_in_group(9), 1);
        assert_eq!(w.pending_in_group(42), 0);
        w.issue(&[a]);
        assert_eq!(w.pending_in_group(7), 1, "in-flight ops are not pending");
        w.requeue(a);
        assert_eq!(w.pending_in_group(7), 2, "requeue restores pending");
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.pending_in_group(7), 1);
    }

    fn ind(stream: u32) -> DispatchRequest {
        req(stream).with_independent(true)
    }

    #[test]
    fn independent_ops_form_a_contiguous_ready_prefix() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap(); // head: always ready
        let b = w.submit(ind(0), 0.0).unwrap();
        let c = w.submit(ind(0), 0.0).unwrap();
        let d = w.submit(req(0), 0.0).unwrap(); // dependent: blocks
        let e = w.submit(ind(0), 0.0).unwrap(); // behind d: blocked too
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Ready));
        assert_eq!(w.state(c), Some(OpState::Ready));
        assert_eq!(w.state(d), Some(OpState::Blocked));
        assert_eq!(w.state(e), Some(OpState::Blocked), "prefix is contiguous");
        assert_eq!(w.ready_count(), 3);
        // issuing the whole prefix at once (one pack) works front-to-back
        w.issue(&[a, b, c]);
        assert_eq!(w.state(d), Some(OpState::Ready), "d is the new front");
        assert_eq!(
            w.state(e),
            Some(OpState::Ready),
            "independent e rejoins the ready prefix behind the new front"
        );
    }

    #[test]
    fn independent_op_can_issue_out_of_prefix_order() {
        // a (front) and b (independent) are both ready; b's pack launches
        // first (e.g. a different shape class won EDF): b leaves from the
        // middle of the queue, a stays issuable
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(ind(0), 0.0).unwrap();
        w.issue(&[b]);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::InFlight));
        w.issue(&[a]);
        w.complete(b);
        w.complete(a);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic(expected = "non-ready")]
    fn blocked_op_behind_dependent_still_panics_on_issue() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let _d = w.submit(req(0), 0.0).unwrap(); // dependent, blocked
        let e = w.submit(ind(0), 0.0).unwrap(); // behind d: blocked
        w.issue(&[e]);
    }

    #[test]
    fn requeue_keeps_independent_successors_ready() {
        let mut w = Window::new(16);
        let a = w.submit(ind(0), 0.0).unwrap();
        let b = w.submit(ind(0), 0.0).unwrap();
        w.issue(&[a]);
        w.requeue(a); // evicted straggler returns to the front
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(
            w.state(b),
            Some(OpState::Ready),
            "independent b stays in the ready prefix behind requeued a"
        );
        w.issue(&[a, b]);
        w.complete(a);
        w.complete(b);
        assert!(w.is_empty());
    }

    #[test]
    fn requeue_of_out_of_order_issued_op_respects_program_order() {
        // a (dependent, seq 0) still pending; b (independent, seq 1) issued
        // out of prefix order, then evicted: b must re-enter BEHIND a — a
        // has no pending predecessors and must keep its readiness, not be
        // demoted behind the returning straggler
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(ind(0), 0.0).unwrap();
        w.issue(&[b]); // legal: b is independent
        w.requeue(b); // evicted straggler
        assert_eq!(
            w.state(a),
            Some(OpState::Ready),
            "a's predecessors are not pending — it stays ready"
        );
        assert_eq!(
            w.state(b),
            Some(OpState::Ready),
            "independent b rejoins the ready prefix behind a"
        );
        w.issue(&[a, b]);
        w.complete(a);
        w.complete(b);
        assert!(w.is_empty());
    }

    #[test]
    fn multiple_requeues_never_leave_a_stale_ready_op() {
        // three dependent ops of one stream issue in order, all straggle,
        // and are requeued out of order (f, e, d): the rebuilt queue must
        // be [e Ready, f Blocked, d Blocked] — a requeued op landing
        // behind a Blocked op must NOT keep a stale Ready state, or the
        // scheduler would issue it out of program order
        let mut w = Window::new(16);
        let e = w.submit(req(0), 0.0).unwrap(); // seq 0
        let f = w.submit(req(0), 0.0).unwrap(); // seq 1
        let d = w.submit(req(0), 0.0).unwrap(); // seq 2
        w.issue(&[e]);
        w.issue(&[f]);
        w.issue(&[d]);
        w.requeue(f);
        w.requeue(e);
        w.requeue(d);
        assert_eq!(w.state(e), Some(OpState::Ready));
        assert_eq!(w.state(f), Some(OpState::Blocked));
        assert_eq!(w.state(d), Some(OpState::Blocked), "no stale Ready");
        // program order drains cleanly
        for id in [e, f, d] {
            w.issue(&[id]);
            w.complete(id);
        }
        assert!(w.is_empty());
    }

    #[test]
    fn requeue_demotes_independent_successors_behind_a_blocked_op() {
        // a(ind seq0), b(dep seq1), c(ind seq2): after a and b issue, c is
        // the ready front. Requeue a, then b: the rebuilt queue [a, b, c]
        // must demote c — the contiguous prefix ends at dependent b, and a
        // stale Ready must not survive behind the freshly-inserted Blocked
        // op (the refresh walk may not stop at the first Blocked entry)
        let mut w = Window::new(16);
        let a = w.submit(ind(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        let c = w.submit(ind(0), 0.0).unwrap();
        w.issue(&[a]);
        w.issue(&[b]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.requeue(a);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(c), Some(OpState::Ready), "c rides behind ready a");
        w.requeue(b);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked), "b waits for a");
        assert_eq!(
            w.state(c),
            Some(OpState::Blocked),
            "contiguous prefix: c demotes behind dependent b"
        );
        w.issue(&[a]);
        assert_eq!(w.state(b), Some(OpState::Ready));
        w.issue(&[b]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.issue(&[c]);
        for id in [a, b, c] {
            w.complete(id);
        }
        assert!(w.is_empty());
    }

    #[test]
    fn inflight_in_group_tracks_issued_ops() {
        let mut w = Window::new(16);
        let a = w.submit(req(0).with_group(7), 0.0).unwrap();
        let b = w.submit(req(1).with_group(7), 0.0).unwrap();
        assert_eq!(w.inflight_in_group(7), 0);
        w.issue(&[a]);
        assert_eq!(w.inflight_in_group(7), 1);
        assert_eq!(w.pending_in_group(7), 1);
        w.issue(&[b]);
        assert_eq!(w.inflight_in_group(7), 2);
        w.requeue(a);
        assert_eq!(w.inflight_in_group(7), 1, "requeue returns op to pending");
        assert_eq!(w.pending_in_group(7), 1);
        w.issue(&[a]);
        w.complete(a);
        w.complete(b);
        assert_eq!(w.inflight_in_group(7), 0);
    }

    #[test]
    fn bookkeeping_bounded_under_tenant_churn() {
        // regression for the window leak: N tenants each submit, run and
        // drain a couple of ops; after the churn every per-stream and
        // per-group map must be empty again, not grown to N entries
        let mut w = Window::new(16);
        for t in 0..200u32 {
            let a = w.submit(req(t).with_group(t as u64), 0.0).unwrap();
            let b = w.submit(ind(t).with_group(t as u64), 0.0).unwrap();
            w.issue(&[a, b]);
            w.complete(a);
            w.complete(b);
            assert!(w.is_empty());
            assert_eq!(w.tracked_streams(), 0, "stream maps leak after tenant {t}");
            assert_eq!(w.tracked_groups(), 0, "group maps leak after tenant {t}");
        }
    }

    #[test]
    fn returning_stream_restarts_clean_after_drain() {
        // a stream that drains completely and comes back gets fresh seq
        // numbering against an empty queue — program order still holds
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.tracked_streams(), 0);
        let b = w.submit(req(0), 1.0).unwrap();
        let c = w.submit(req(0), 1.0).unwrap();
        assert_eq!(w.get(b).unwrap().seq, 0, "fresh life restarts at seq 0");
        assert_eq!(w.get(c).unwrap().seq, 1);
        assert_eq!(w.state(b), Some(OpState::Ready));
        assert_eq!(w.state(c), Some(OpState::Blocked));
    }

    #[test]
    fn ready_delta_log_mirrors_state_transitions() {
        let mut w = Window::new(16);
        let mut log = Vec::new();
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap(); // blocked: no delta
        assert!(!w.take_ready_deltas(&mut log), "no overflow");
        assert_eq!(log, vec![ReadyDelta::Enter(a)]);
        w.issue(&[a]); // a leaves the ready set, b becomes the front
        assert!(!w.take_ready_deltas(&mut log));
        assert_eq!(log, vec![ReadyDelta::Leave(a), ReadyDelta::Enter(b)]);
        w.requeue(a); // straggler returns ahead of b; b demotes behind it
        assert!(!w.take_ready_deltas(&mut log));
        assert_eq!(log, vec![ReadyDelta::Enter(a), ReadyDelta::Leave(b)]);
        // a drained log stays drained
        assert!(!w.take_ready_deltas(&mut log));
        assert!(log.is_empty());
    }

    #[test]
    fn ready_delta_log_overflow_reports_resync() {
        let mut w = Window::new(10_000);
        for _ in 0..(super::DELTA_LOG_CAP + 5) {
            w.submit(ind(0), 0.0).unwrap();
        }
        let mut log = Vec::new();
        assert!(w.take_ready_deltas(&mut log), "overflowed log must say so");
        assert_eq!(log.len(), super::DELTA_LOG_CAP, "recording stopped at cap");
        // the overflow flag clears with the drain that reported it
        assert!(!w.take_ready_deltas(&mut log));
        assert!(log.is_empty());
    }

    #[test]
    fn earliest_deadline_tracks_ready_only() {
        let mut w = Window::new(16);
        let a = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 5_000.0),
                0.0,
            )
            .unwrap();
        let _b = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 1_000.0),
                0.0,
            )
            .unwrap();
        // b has the tighter deadline but is blocked behind a
        assert_eq!(w.earliest_deadline(), Some(5_000.0));
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.earliest_deadline(), Some(1_000.0));
    }
}
