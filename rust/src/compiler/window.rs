//! The OoO issue window.
//!
//! Holds pending [`TensorOp`]s from all streams, tracks per-stream program
//! order and deadline bookkeeping. This is the VLIW analogy's instruction
//! window: the scheduler picks ready ops out of order, the coalescer packs
//! them into long words.
//!
//! Readiness is *issue-order*, not completion-order: an op is ready once
//! every earlier op of its stream has been **issued**. Program order is
//! still enforced at issue time (a stream's ops enter the device in
//! sequence), but a stream may have several ops in flight at once — the
//! pipelining the concurrent launch stage needs. Deployments that require
//! a completion barrier between a stream's ops get it for free in the
//! synchronous drive mode, where every launch completes before the next
//! decision.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::compiler::ir::{DispatchRequest, OpId, StreamId, TensorOp};

/// Issue-window state for one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    /// Waiting on an earlier op of the same stream to issue.
    Blocked,
    /// Eligible for issue.
    Ready,
    /// Issued to the executor, not yet complete.
    InFlight,
}

/// The out-of-order issue window.
#[derive(Debug, Default)]
pub struct Window {
    ops: HashMap<OpId, (TensorOp, OpState)>,
    /// per-stream queue of pending (un-issued) op ids in program order
    streams: BTreeMap<StreamId, VecDeque<OpId>>,
    /// per-stream next sequence number
    next_seq: HashMap<StreamId, u64>,
    /// per-stream in-flight count (several ops of one stream may be in
    /// flight at once under the concurrent launch stage)
    inflight: HashMap<StreamId, usize>,
    /// per-group pending (un-issued) op count — the admission layer's
    /// queue-depth signal
    group_pending: HashMap<u64, usize>,
    next_id: u64,
    capacity: usize,
}

impl Window {
    /// Window with a capacity bound (admission control backstop).
    pub fn new(capacity: usize) -> Self {
        Window {
            capacity,
            ..Default::default()
        }
    }

    /// Number of pending + in-flight ops.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if no ops are pending or in flight.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// True if at capacity (submit would fail).
    pub fn is_full(&self) -> bool {
        self.ops.len() >= self.capacity
    }

    /// Pending (un-issued) ops in a coalescing group — the serving layer's
    /// per-model queue depth.
    pub fn pending_in_group(&self, group: u64) -> usize {
        self.group_pending.get(&group).copied().unwrap_or(0)
    }

    /// Submit a dispatch request at time `now`. Returns the assigned op id,
    /// or `None` when the window is full (caller applies backpressure).
    pub fn submit(&mut self, req: DispatchRequest, now: f64) -> Option<OpId> {
        if self.is_full() {
            return None;
        }
        let id = OpId(self.next_id);
        self.next_id += 1;
        let seq_ref = self.next_seq.entry(req.stream).or_insert(0);
        let seq = *seq_ref;
        *seq_ref += 1;
        let op = TensorOp {
            id,
            stream: req.stream,
            seq,
            kernel: req.kernel,
            arrival_us: now,
            deadline_us: now + req.slo_us,
            group: req.group,
            tag: req.tag,
        };
        let q = self.streams.entry(req.stream).or_default();
        // ready iff nothing earlier from this stream awaits issue
        let state = if q.is_empty() {
            OpState::Ready
        } else {
            OpState::Blocked
        };
        q.push_back(id);
        *self.group_pending.entry(req.group).or_insert(0) += 1;
        self.ops.insert(id, (op, state));
        Some(id)
    }

    /// All currently ready ops (unordered; scheduler imposes policy order).
    pub fn ready(&self) -> Vec<&TensorOp> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op)
            .collect()
    }

    /// Number of ready ops.
    pub fn ready_count(&self) -> usize {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .count()
    }

    /// Look up an op.
    pub fn get(&self, id: OpId) -> Option<&TensorOp> {
        self.ops.get(&id).map(|(op, _)| op)
    }

    /// State of an op.
    pub fn state(&self, id: OpId) -> Option<OpState> {
        self.ops.get(&id).map(|(_, s)| *s)
    }

    /// Mark ops as issued (Ready → InFlight), unblocking each stream's
    /// successor. Panics if any op is not ready — the scheduler must never
    /// issue blocked ops.
    pub fn issue(&mut self, ids: &[OpId]) {
        for id in ids {
            let (op, state) = self.ops.get_mut(id).expect("issue of unknown op");
            assert_eq!(
                *state,
                OpState::Ready,
                "scheduler issued non-ready op {id:?}"
            );
            *state = OpState::InFlight;
            let (stream, group) = (op.stream, op.group);
            *self.inflight.entry(stream).or_insert(0) += 1;
            let pending = self
                .group_pending
                .get_mut(&group)
                .expect("group pending count");
            *pending -= 1;
            // pop from the stream queue head (must be the head by program
            // order; ready implies it is)
            let q = self.streams.get_mut(&stream).expect("stream queue");
            let head = q.pop_front().expect("queue non-empty");
            assert_eq!(head, *id, "program order violated on issue");
            // the next op of this stream (if any) becomes ready: program
            // order is enforced at issue, not at completion
            if let Some(&next) = q.front() {
                if let Some((_, s)) = self.ops.get_mut(&next) {
                    *s = OpState::Ready;
                }
            }
        }
    }

    /// Complete an in-flight op. Returns the completed op.
    pub fn complete(&mut self, id: OpId) -> TensorOp {
        let (op, state) = self.ops.remove(&id).expect("complete of unknown op");
        assert_eq!(state, OpState::InFlight, "complete of non-inflight op");
        let cnt = self.inflight.get_mut(&op.stream).expect("inflight count");
        *cnt -= 1;
        op
    }

    /// Re-queue an evicted in-flight op (straggler eviction, §5.2): it goes
    /// back to the *front* of its stream as Ready with its original
    /// deadline, so the scheduler re-prioritizes it immediately. The
    /// previous head (if any) blocks again behind it.
    pub fn requeue(&mut self, id: OpId) {
        let (op, state) = self.ops.get_mut(&id).expect("requeue of unknown op");
        assert_eq!(*state, OpState::InFlight, "requeue of non-inflight op");
        *state = OpState::Ready;
        let (stream, group) = (op.stream, op.group);
        let cnt = self.inflight.get_mut(&stream).expect("inflight count");
        *cnt -= 1;
        *self.group_pending.entry(group).or_insert(0) += 1;
        let q = self.streams.entry(stream).or_default();
        if let Some(&old_head) = q.front() {
            if let Some((_, s)) = self.ops.get_mut(&old_head) {
                *s = OpState::Blocked;
            }
        }
        q.push_front(id);
    }

    /// Earliest deadline among ready ops (scheduler's EDF pivot).
    pub fn earliest_deadline(&self) -> Option<f64> {
        self.ops
            .values()
            .filter(|(_, s)| *s == OpState::Ready)
            .map(|(op, _)| op.deadline_us)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::kernel::KernelDesc;

    fn req(stream: u32) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(32, 256, 64), 10_000.0)
    }

    #[test]
    fn submit_assigns_program_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 1.0).unwrap();
        assert_eq!(w.get(a).unwrap().seq, 0);
        assert_eq!(w.get(b).unwrap().seq, 1);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
    }

    #[test]
    fn streams_are_independent() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(1), 0.0).unwrap();
        // different stream: immediately ready despite stream 0's pending op
        assert_eq!(w.state(b), Some(OpState::Ready));
        assert_eq!(w.ready_count(), 2);
    }

    #[test]
    fn issue_unblocks_successor_for_pipelining() {
        // issue-order readiness: b becomes ready as soon as a is issued,
        // so one stream can keep several ops in flight
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        assert_eq!(w.state(b), Some(OpState::Ready));
        w.issue(&[b]);
        assert_eq!(w.state(a), Some(OpState::InFlight));
        assert_eq!(w.state(b), Some(OpState::InFlight));
        w.complete(a);
        w.complete(b);
        assert!(w.is_empty());
    }

    #[test]
    fn completion_order_free_within_stream() {
        // two in-flight ops of one stream may complete out of order
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.issue(&[b]);
        w.complete(b);
        w.complete(a);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic(expected = "non-ready")]
    fn issuing_blocked_op_panics() {
        let mut w = Window::new(16);
        let _a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[b]);
    }

    #[test]
    fn capacity_backpressure() {
        let mut w = Window::new(2);
        assert!(w.submit(req(0), 0.0).is_some());
        assert!(w.submit(req(1), 0.0).is_some());
        assert!(w.submit(req(2), 0.0).is_none());
        assert!(w.is_full());
    }

    #[test]
    fn requeue_restores_readiness_and_order() {
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.requeue(a); // evicted straggler
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(b), Some(OpState::Blocked));
        // must issue a before b again
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.state(b), Some(OpState::Ready));
    }

    #[test]
    fn requeue_with_multiple_inflight_ops_per_stream() {
        // a and b both in flight; a straggles and is evicted: it must come
        // back at the *front* of the stream, ahead of pending c, while b
        // stays in flight and can still complete
        let mut w = Window::new(16);
        let a = w.submit(req(0), 0.0).unwrap();
        let b = w.submit(req(0), 0.0).unwrap();
        let c = w.submit(req(0), 0.0).unwrap();
        w.issue(&[a]);
        w.issue(&[b]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.requeue(a);
        assert_eq!(w.state(a), Some(OpState::Ready));
        assert_eq!(w.state(c), Some(OpState::Blocked), "a re-enters ahead of c");
        assert_eq!(w.state(b), Some(OpState::InFlight));
        w.complete(b); // out-of-order completion is fine
        w.issue(&[a]);
        assert_eq!(w.state(c), Some(OpState::Ready));
        w.complete(a);
        w.issue(&[c]);
        w.complete(c);
        assert!(w.is_empty());
    }

    #[test]
    fn group_pending_tracks_unissued_ops() {
        let mut w = Window::new(16);
        let a = w
            .submit(req(0).with_group(7), 0.0)
            .unwrap();
        let _b = w.submit(req(1).with_group(7), 0.0).unwrap();
        let _c = w.submit(req(2).with_group(9), 0.0).unwrap();
        assert_eq!(w.pending_in_group(7), 2);
        assert_eq!(w.pending_in_group(9), 1);
        assert_eq!(w.pending_in_group(42), 0);
        w.issue(&[a]);
        assert_eq!(w.pending_in_group(7), 1, "in-flight ops are not pending");
        w.requeue(a);
        assert_eq!(w.pending_in_group(7), 2, "requeue restores pending");
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.pending_in_group(7), 1);
    }

    #[test]
    fn earliest_deadline_tracks_ready_only() {
        let mut w = Window::new(16);
        let a = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 5_000.0),
                0.0,
            )
            .unwrap();
        let _b = w
            .submit(
                DispatchRequest::new(StreamId(0), KernelDesc::gemm(1, 1, 1), 1_000.0),
                0.0,
            )
            .unwrap();
        // b has the tighter deadline but is blocked behind a
        assert_eq!(w.earliest_deadline(), Some(5_000.0));
        w.issue(&[a]);
        w.complete(a);
        assert_eq!(w.earliest_deadline(), Some(1_000.0));
    }
}
