//! Declarative dispatch IR (§5.1).
//!
//! The programmer "specifies the operators, the inputs, and latency
//! constraints" — never thread/block geometry. A [`TensorOp`] is the unit
//! the JIT schedules: one algebraic tensor operation from one stream of
//! execution, carrying its deadline. The JIT, not the programmer, decides
//! the launch configuration, the packing and the issue time (*late
//! binding*, *context aware*).

use crate::gpu::kernel::KernelDesc;

/// SLO class of a request — the one priority dimension threaded through
/// every scheduling layer (frontend gate, admission, scheduler, coalescer,
/// eviction, metrics). Classes never share a launch: the coalescer buckets
/// by class, so a best-effort pack can be staggered or evicted without
/// touching critical work.
///
/// Ordering is by urgency: `Critical < Standard < BestEffort`, so sorting
/// ascending puts the most latency-sensitive class first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum SloClass {
    /// Latency-critical traffic: keeps today's admission pricing, never
    /// shed ahead of lower classes, highest fair-share weight.
    Critical,
    /// The default class — exactly the pre-class behaviour (weight 1.0).
    #[default]
    Standard,
    /// Batch/background traffic: shed first under stale or loaded
    /// admission views, packs yield to tight higher-class slack, evicted
    /// on a tighter straggler threshold.
    BestEffort,
}

impl SloClass {
    /// All classes, in urgency order (index order).
    pub const ALL: [SloClass; 3] = [SloClass::Critical, SloClass::Standard, SloClass::BestEffort];

    /// Dense index (Critical = 0, Standard = 1, BestEffort = 2) — used to
    /// key per-class arrays in `Policy` and `ServeMetrics`.
    pub fn index(self) -> usize {
        match self {
            SloClass::Critical => 0,
            SloClass::Standard => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Inverse of [`SloClass::index`]; out-of-range maps to Standard.
    pub fn from_index(i: usize) -> SloClass {
        match i {
            0 => SloClass::Critical,
            2 => SloClass::BestEffort,
            _ => SloClass::Standard,
        }
    }

    /// Human-readable name (bench JSON field prefix).
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Critical => "critical",
            SloClass::Standard => "standard",
            SloClass::BestEffort => "best_effort",
        }
    }

    /// Parse a class name (CLI `--classes` spec). Accepts the JSON field
    /// prefixes and common short forms.
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "critical" | "crit" => Some(SloClass::Critical),
            "standard" | "std" => Some(SloClass::Standard),
            "best_effort" | "best-effort" | "be" | "batch" => Some(SloClass::BestEffort),
            _ => None,
        }
    }
}

/// Identifier of a stream of execution (a tenant's command stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub u32);

/// Identifier of a scheduled op, unique within a JIT instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u64);

/// One declaratively-dispatched tensor op.
#[derive(Debug, Clone)]
pub struct TensorOp {
    /// Unique id (assigned by the window on submit).
    pub id: OpId,
    /// Issuing stream.
    pub stream: StreamId,
    /// Position in the stream's program order; unless the op is marked
    /// [`TensorOp::independent`], op `seq` is only ready once op `seq−1` of
    /// the same stream issued (data dependence within a stream — streams
    /// are mutually independent, §1).
    pub seq: u64,
    /// True when this op carries no data dependence on earlier ops of its
    /// stream (serving: stateless inference requests). Independent ops may
    /// become ready while earlier stream ops are still pending, ride the
    /// same superkernel launch as other ops of their stream, and issue out
    /// of program order. Ops with the flag unset (the default) keep strict
    /// per-stream issue order.
    pub independent: bool,
    /// The tensor operation, already lowered to its GEMM form.
    pub kernel: KernelDesc,
    /// Submission time, µs.
    pub arrival_us: f64,
    /// Absolute deadline, µs (arrival + the stream's SLO share).
    pub deadline_us: f64,
    /// Coalescing group: ops only pack with ops of the same group. The
    /// serving layer keys this by model, so two models whose request
    /// shapes quantize to the same class never share a launch.
    pub group: u64,
    /// Opaque request handle for completion fan-out (serving layer).
    pub tag: u64,
    /// SLO class of the issuing tenant. Classes never coalesce together
    /// and the scheduler weights deadlines by class (see
    /// [`crate::compiler::scheduler::Policy::class_weights`]).
    pub class: SloClass,
}

impl TensorOp {
    /// Slack remaining at `now` given an estimated execution time.
    pub fn slack_us(&self, now: f64, est_exec_us: f64) -> f64 {
        self.deadline_us - now - est_exec_us
    }

    /// True if issuing at `now` with estimate `est` would already be late.
    pub fn is_critical(&self, now: f64, est_exec_us: f64) -> bool {
        self.slack_us(now, est_exec_us) <= 0.0
    }
}

/// Builder for submitting ops (the public declarative API).
#[derive(Debug, Clone)]
pub struct DispatchRequest {
    /// Issuing stream.
    pub stream: StreamId,
    /// The operation.
    pub kernel: KernelDesc,
    /// Relative SLO budget for this op, µs.
    pub slo_us: f64,
    /// Coalescing group (see [`TensorOp::group`]).
    pub group: u64,
    /// Opaque completion tag.
    pub tag: u64,
    /// Independence of earlier ops in the stream (see
    /// [`TensorOp::independent`]).
    pub independent: bool,
    /// SLO class (see [`TensorOp::class`]); defaults to
    /// [`SloClass::Standard`], which reproduces pre-class behaviour.
    pub class: SloClass,
}

impl DispatchRequest {
    /// Declarative dispatch: operator + input shapes + latency constraint.
    pub fn new(stream: StreamId, kernel: KernelDesc, slo_us: f64) -> Self {
        Self {
            stream,
            kernel,
            slo_us,
            group: 0,
            tag: 0,
            independent: false,
            class: SloClass::Standard,
        }
    }

    /// Attach a completion tag.
    pub fn with_tag(mut self, tag: u64) -> Self {
        self.tag = tag;
        self
    }

    /// Restrict coalescing to a group (the serving layer's model key).
    pub fn with_group(mut self, group: u64) -> Self {
        self.group = group;
        self
    }

    /// Declare this request independent of its stream's earlier ops
    /// (serving: stateless inference). Independent ops may coalesce with
    /// other ops of their own stream into one launch; ops with the flag
    /// unset keep strict per-stream program order.
    pub fn with_independent(mut self, independent: bool) -> Self {
        self.independent = independent;
        self
    }

    /// Assign the request an SLO class (per-tenant in the serving layer).
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::kernel::KernelDesc;

    #[test]
    fn slack_and_criticality() {
        let op = TensorOp {
            id: OpId(1),
            stream: StreamId(0),
            seq: 0,
            kernel: KernelDesc::gemm(32, 256, 256),
            arrival_us: 0.0,
            deadline_us: 1_000.0,
            group: 0,
            tag: 0,
            independent: false,
            class: SloClass::Standard,
        };
        assert_eq!(op.slack_us(200.0, 300.0), 500.0);
        assert!(!op.is_critical(200.0, 300.0));
        assert!(op.is_critical(900.0, 300.0));
    }

    #[test]
    fn dispatch_request_builder() {
        let r = DispatchRequest::new(StreamId(3), KernelDesc::gemm(1, 2, 3), 5_000.0)
            .with_tag(77)
            .with_group(4);
        assert_eq!(r.stream, StreamId(3));
        assert_eq!(r.tag, 77);
        assert_eq!(r.group, 4);
        assert_eq!(r.slo_us, 5_000.0);
        assert!(!r.independent, "program order binds by default");
        assert_eq!(r.class, SloClass::Standard, "Standard class by default");
        let r = r.with_independent(true).with_class(SloClass::BestEffort);
        assert!(r.independent);
        assert_eq!(r.class, SloClass::BestEffort);
    }

    #[test]
    fn slo_class_index_roundtrip_and_names() {
        for (i, c) in SloClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(SloClass::from_index(i), *c);
            assert_eq!(SloClass::parse(c.name()), Some(*c));
        }
        assert_eq!(SloClass::default(), SloClass::Standard);
        assert_eq!(SloClass::parse("be"), Some(SloClass::BestEffort));
        assert_eq!(SloClass::parse("nope"), None);
        // urgency order: sorting ascending puts Critical first
        assert!(SloClass::Critical < SloClass::Standard);
        assert!(SloClass::Standard < SloClass::BestEffort);
    }
}
