//! Ahead-of-time autotuning (§5.3, Table 1).
//!
//! Searches the blocking-configuration space for two objectives:
//!
//! * **greedy** — maximize *isolated* throughput (what every vendor library
//!   ships: the kernel assumes it owns the GPU);
//! * **collaborative** — maximize *multiplexed* throughput with `tenants`
//!   co-resident copies: a smaller per-launch SM footprint (fewer, beefier
//!   blocks and/or lower shared-memory residency) so concurrent kernels
//!   stop thrashing shared state and leave SMs for each other.
//!
//! The paper's Table 1 result — collaborative kernels lose ~20% alone but
//! win 1.25–1.36× when multiplexed — emerges from the search, it is not
//! hard-coded. The chosen configs feed the Pallas `CONFIGS` table (L1) and
//! the JIT's runtime packing decisions.

use crate::gpu::cost::CostModel;
use crate::gpu::kernel::{KernelDesc, LaunchConfig};
use crate::gpu::timeline::{SharingModel, SharingSim, SimKernel};

/// Search space of tile sizes.
pub const TILE_CHOICES: [u32; 4] = [32, 64, 128, 256];
/// Search space of contraction slabs.
pub const TK_CHOICES: [u32; 3] = [16, 32, 64];

/// Residency a (tm, tn, tk) config demands from an SM: double-buffered
/// A/B slabs in shared memory against a 128 KiB budget (V100-like).
pub fn residency_of(tm: u32, tn: u32, tk: u32) -> f64 {
    let smem = 2 * 4 * (tm * tk + tk * tn); // double-buffered f32 slabs
    (smem as f64 / (128.0 * 1024.0)).clamp(0.05, 0.95)
}

/// One tuned configuration with its measured objectives.
#[derive(Debug, Clone, Copy)]
pub struct TunedConfig {
    /// The configuration.
    pub config: LaunchConfig,
    /// Isolated throughput, TFLOPS.
    pub isolated_tflops: f64,
    /// Multiplexed aggregate throughput with `tenants` copies, TFLOPS.
    pub multiplexed_tflops: f64,
}

/// Table-1 style autotuning outcome.
#[derive(Debug, Clone, Copy)]
pub struct AutotuneResult {
    /// Best config by isolated throughput.
    pub greedy: TunedConfig,
    /// Best config by multiplexed throughput.
    pub collaborative: TunedConfig,
    /// Co-tenancy level used for the multiplexed objective.
    pub tenants: u32,
}

impl AutotuneResult {
    /// Multiplexed speedup of collaborative over greedy (paper: 1.25×).
    pub fn multiplexed_speedup(&self) -> f64 {
        self.collaborative.multiplexed_tflops / self.greedy.multiplexed_tflops
    }

    /// Isolated slowdown of collaborative vs greedy (paper: ~20%).
    pub fn isolated_degradation(&self) -> f64 {
        1.0 - self.collaborative.isolated_tflops / self.greedy.isolated_tflops
    }
}

/// Measure one config under both objectives.
pub fn measure(
    cm: &CostModel,
    k: &KernelDesc,
    cfg: &LaunchConfig,
    tenants: u32,
    sharing: &SharingModel,
) -> TunedConfig {
    let prof = cm.profile(k, cfg);
    let isolated_tflops = k.flops() / prof.duration_us / 1e6;
    // multiplexed: `tenants` copies dispatched concurrently, same config
    let kernels: Vec<SimKernel> = (0..tenants)
        .map(|s| SimKernel {
            id: s as u64,
            stream: s,
            profile: prof,
            arrival_us: 0.0,
        })
        .collect();
    let res = SharingSim::new(sharing.clone()).run(&kernels);
    let multiplexed_tflops = k.flops() * tenants as f64 / res.makespan_us / 1e6;
    TunedConfig {
        config: *cfg,
        isolated_tflops,
        multiplexed_tflops,
    }
}

/// Full grid search producing the Table 1 pair.
pub fn autotune(
    cm: &CostModel,
    k: &KernelDesc,
    tenants: u32,
    sharing: &SharingModel,
) -> AutotuneResult {
    let mut best_iso: Option<TunedConfig> = None;
    let mut best_mux: Option<TunedConfig> = None;
    for &tm in &TILE_CHOICES {
        for &tn in &TILE_CHOICES {
            for &tk in &TK_CHOICES {
                let cfg = LaunchConfig {
                    tm,
                    tn,
                    tk,
                    residency: residency_of(tm, tn, tk),
                };
                let t = measure(cm, k, &cfg, tenants, sharing);
                if best_iso.map_or(true, |b| t.isolated_tflops > b.isolated_tflops) {
                    best_iso = Some(t);
                }
                if best_mux.map_or(true, |b| t.multiplexed_tflops > b.multiplexed_tflops) {
                    best_mux = Some(t);
                }
            }
        }
    }
    AutotuneResult {
        greedy: best_iso.expect("non-empty grid"),
        collaborative: best_mux.expect("non-empty grid"),
        tenants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_kernel() -> KernelDesc {
        // the Table 1 workload: a conv2_2-class SGEMM
        KernelDesc::gemm(3136, 576, 64)
    }

    #[test]
    fn residency_monotone_in_tiles() {
        assert!(residency_of(128, 128, 32) > residency_of(64, 64, 32));
        assert!(residency_of(64, 64, 64) > residency_of(64, 64, 32));
        let r = residency_of(256, 256, 64);
        assert!(r <= 0.95);
    }

    #[test]
    fn table1_shape_emerges() {
        let cm = CostModel::v100();
        let res = autotune(&cm, &conv_kernel(), 6, &SharingModel::default());
        // collaborative must win multiplexed…
        assert!(
            res.multiplexed_speedup() >= 1.0,
            "mux speedup {}",
            res.multiplexed_speedup()
        );
        // …and the greedy config must be at least as good alone
        assert!(res.isolated_degradation() >= -1e-9);
        // the paper's magnitudes: 1.1–1.8x mux win, ≤50% isolated loss
        assert!(
            res.multiplexed_speedup() < 2.5,
            "mux speedup {} out of plausible range",
            res.multiplexed_speedup()
        );
        assert!(res.isolated_degradation() < 0.5);
    }

    #[test]
    fn collaborative_config_has_smaller_sm_footprint() {
        // the collaborative kernel must leave room for co-tenants: fewer
        // blocks in flight (SM footprint) and/or lower smem residency
        let cm = CostModel::v100();
        let k = conv_kernel();
        let res = autotune(&cm, &k, 6, &SharingModel::default());
        let g = &res.greedy.config;
        let c = &res.collaborative.config;
        let footprint = |cfg: &LaunchConfig| cfg.blocks(&k) as f64 * cfg.residency;
        assert!(
            c.blocks(&k) <= g.blocks(&k) || footprint(c) <= footprint(g),
            "collab {c:?} ({} blocks) vs greedy {g:?} ({} blocks)",
            c.blocks(&k),
            g.blocks(&k)
        );
    }

    #[test]
    fn measure_is_deterministic() {
        let cm = CostModel::v100();
        let cfg = LaunchConfig::greedy();
        let a = measure(&cm, &conv_kernel(), &cfg, 4, &SharingModel::default());
        let b = measure(&cm, &conv_kernel(), &cfg, 4, &SharingModel::default());
        assert_eq!(a.isolated_tflops, b.isolated_tflops);
        assert_eq!(a.multiplexed_tflops, b.multiplexed_tflops);
    }

    #[test]
    fn collaborative_wins_at_every_tenancy_level() {
        // the discrete grid makes the speedup non-monotone in tenant count,
        // but collaborative must never lose the multiplexed objective
        let cm = CostModel::v100();
        let k = conv_kernel();
        for tenants in [2u32, 4, 6, 8] {
            let r = autotune(&cm, &k, tenants, &SharingModel::default());
            assert!(
                r.multiplexed_speedup() >= 1.0,
                "tenants={tenants}: speedup {}",
                r.multiplexed_speedup()
            );
        }
    }
}
