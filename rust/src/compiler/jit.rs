//! The JIT issue loop: window + scheduler + coalescer + executor.
//!
//! `JitCompiler` is the synchronous core shared by both deployment modes:
//!
//! * **virtual time** (benches, simulator executor): `run_trace` replays a
//!   timed op trace, advancing a virtual clock through scheduler decisions;
//! * **real time** (`serve::server`, PJRT executor): the serving loop calls
//!   `submit`/`pump` with wall-clock timestamps.
//!
//! The executor is abstract ([`KernelExecutor`]): the V100 cost model backs
//! the paper's figures, the PJRT CPU client backs the real end-to-end path.

use crate::compiler::coalescer::{Coalescer, SuperKernel};
use crate::compiler::ir::{DispatchRequest, OpId, TensorOp};
use crate::compiler::scheduler::{Decision, Policy, Scheduler};
use crate::compiler::window::Window;
use crate::gpu::kernel::KernelDesc;

/// Backend abstraction: estimate and execute batched kernels.
pub trait KernelExecutor {
    /// Estimated execution time of a batched kernel, µs (scheduler input).
    fn estimate_us(&self, k: &KernelDesc) -> f64;
    /// Execute a superkernel; returns the actual wall/virtual duration, µs.
    fn execute(&mut self, sk: &SuperKernel) -> f64;
}

/// JIT configuration.
#[derive(Debug, Clone)]
pub struct JitConfig {
    /// Scheduling policy.
    pub policy: Policy,
    /// Packing rules.
    pub coalescer: Coalescer,
    /// Issue-window capacity (backpressure bound).
    pub window_capacity: usize,
    /// Per-launch JIT bookkeeping overhead, µs (measured by perf_hotpath).
    pub packing_overhead_us: f64,
}

impl Default for JitConfig {
    fn default() -> Self {
        JitConfig {
            policy: Policy::default(),
            coalescer: Coalescer::default(),
            window_capacity: 1024,
            packing_overhead_us: 2.0,
        }
    }
}

/// Completion record for one op.
#[derive(Debug, Clone)]
pub struct OpCompletion {
    /// The op.
    pub op: TensorOp,
    /// Issue time, µs.
    pub issue_us: f64,
    /// Completion time, µs.
    pub done_us: f64,
    /// Problems in the superkernel it rode in.
    pub pack_size: usize,
    /// True if the op met its deadline.
    pub met_deadline: bool,
    /// True if the launch was evicted once as a straggler and retried.
    pub evicted: bool,
}

impl OpCompletion {
    /// End-to-end latency, µs.
    pub fn latency_us(&self) -> f64 {
        self.done_us - self.op.arrival_us
    }
}

/// Aggregate JIT statistics.
#[derive(Debug, Clone, Default)]
pub struct JitStats {
    /// Superkernels launched.
    pub launches: u64,
    /// Ops completed.
    pub ops: u64,
    /// Useful FLOPs (pre-padding).
    pub useful_flops: f64,
    /// Launched FLOPs (incl. padding).
    pub launched_flops: f64,
    /// Device-busy virtual time, µs.
    pub busy_us: f64,
    /// Deadline hits.
    pub slo_hits: u64,
    /// Deadline misses.
    pub slo_misses: u64,
    /// Straggler evictions (§5.2).
    pub evictions: u64,
}

impl JitStats {
    /// Mean problems per launch (VLIW word occupancy).
    pub fn mean_pack(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.ops as f64 / self.launches as f64
        }
    }

    /// FLOP padding efficiency.
    pub fn pack_efficiency(&self) -> f64 {
        if self.launched_flops <= 0.0 {
            1.0
        } else {
            self.useful_flops / self.launched_flops
        }
    }

    /// SLO attainment fraction.
    pub fn slo_attainment(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }
}

/// The OoO VLIW JIT compiler instance.
pub struct JitCompiler<E: KernelExecutor> {
    /// Issue window.
    pub window: Window,
    scheduler: Scheduler,
    executor: E,
    cfg: JitConfig,
    /// Virtual/wall clock, µs.
    pub now_us: f64,
    /// Aggregate stats.
    pub stats: JitStats,
}

impl<E: KernelExecutor> JitCompiler<E> {
    /// New JIT over an executor.
    pub fn new(cfg: JitConfig, executor: E) -> Self {
        JitCompiler {
            window: Window::new(cfg.window_capacity),
            scheduler: Scheduler::new(cfg.policy.clone(), cfg.coalescer.clone()),
            executor,
            cfg,
            now_us: 0.0,
            stats: JitStats::default(),
        }
    }

    /// Borrow the executor.
    pub fn executor(&self) -> &E {
        &self.executor
    }

    /// Submit an op at the current clock. Returns None on backpressure.
    pub fn submit(&mut self, req: DispatchRequest) -> Option<OpId> {
        self.window.submit(req, self.now_us)
    }

    /// Drive the loop at the current instant: launch everything the policy
    /// allows. Returns completions and the time the next decision is due
    /// (None = window drained or all blocked).
    pub fn pump(&mut self) -> (Vec<OpCompletion>, Option<f64>) {
        let mut out = Vec::new();
        loop {
            let est = {
                let ex = &self.executor;
                move |k: &KernelDesc| ex.estimate_us(k)
            };
            match self.scheduler.decide(&self.window, self.now_us, est) {
                Decision::Idle => return (out, None),
                Decision::Wait { until_us } => return (out, Some(until_us)),
                Decision::Launch(pack) => {
                    out.extend(self.launch(pack));
                }
            }
        }
    }

    /// Execute one superkernel synchronously, advancing the clock by its
    /// duration (+ packing overhead), applying straggler eviction (§5.2):
    /// if the actual runtime blows past `eviction_factor ×` estimate, the
    /// launch is evicted and retried once (counted in stats).
    fn launch(&mut self, pack: SuperKernel) -> Vec<OpCompletion> {
        self.window.issue(&pack.ops);
        let issue_us = self.now_us;
        let est = self.executor.estimate_us(&pack.kernel);
        let mut dur = self.executor.execute(&pack.kernel_for_exec());
        let mut evicted = false;
        if self
            .scheduler
            .should_evict(issue_us, est, issue_us + dur)
        {
            // evict + retry once: pay the straggler time up to the eviction
            // point, then a clean re-run at estimate
            self.stats.evictions += 1;
            evicted = true;
            dur = self.cfg.policy.eviction_factor * est + est;
        }
        let total = dur + self.cfg.packing_overhead_us;
        self.now_us += total;
        self.stats.busy_us += total;
        self.stats.launches += 1;
        self.stats.useful_flops += pack.useful_flops;
        self.stats.launched_flops += pack.kernel.flops();
        let done_us = self.now_us;
        pack.ops
            .iter()
            .map(|id| {
                let op = self.window.complete(*id);
                let met = done_us <= op.deadline_us;
                if met {
                    self.stats.slo_hits += 1;
                } else {
                    self.stats.slo_misses += 1;
                }
                self.stats.ops += 1;
                OpCompletion {
                    op,
                    issue_us,
                    done_us,
                    pack_size: pack.ops.len(),
                    met_deadline: met,
                    evicted,
                }
            })
            .collect()
    }

    /// Replay a timed trace in virtual time. `ops` must be sorted by
    /// arrival. Returns all completions.
    pub fn run_trace(&mut self, ops: Vec<(f64, DispatchRequest)>) -> Vec<OpCompletion> {
        let mut out = Vec::new();
        let mut next = 0usize;
        loop {
            // admit everything that has arrived
            while next < ops.len() && ops[next].0 <= self.now_us + 1e-9 {
                let (_, req) = ops[next].clone();
                if self.submit(req).is_none() {
                    // backpressure in virtual time: let the device catch up
                    break;
                }
                next += 1;
            }
            let (done, wake) = self.pump();
            out.extend(done);
            let next_arrival = ops.get(next).map(|(t, _)| *t);
            match (wake, next_arrival) {
                (None, None) if self.window.is_empty() => break,
                (None, None) => {
                    // all blocked with nothing arriving: should not happen
                    // (blocked implies in-flight, and launch is synchronous)
                    unreachable!("deadlocked window");
                }
                (None, Some(t)) => self.now_us = self.now_us.max(t),
                (Some(w), None) => self.now_us = self.now_us.max(w),
                (Some(w), Some(t)) => self.now_us = self.now_us.max(w.min(t)),
            }
        }
        out
    }
}

impl SuperKernel {
    /// The kernel actually executed (identical; hook for future fusion).
    fn kernel_for_exec(&self) -> SuperKernel {
        self.clone()
    }
}

/// Simulator-backed executor: durations from the V100 cost model, with an
/// optional deterministic straggler injector for eviction tests.
pub struct SimExecutor {
    /// Cost model.
    pub cm: crate::gpu::cost::CostModel,
    /// Launch config used for superkernels.
    pub cfg: crate::gpu::kernel::LaunchConfig,
    /// Every `straggle_every`-th launch runs `straggle_factor×` slower
    /// (0 = never).
    pub straggle_every: u64,
    /// Straggler slowdown factor.
    pub straggle_factor: f64,
    counter: u64,
}

impl SimExecutor {
    /// V100-backed executor with the greedy config.
    pub fn v100() -> Self {
        SimExecutor {
            cm: crate::gpu::cost::CostModel::v100(),
            cfg: crate::gpu::kernel::LaunchConfig::greedy(),
            straggle_every: 0,
            straggle_factor: 5.0,
            counter: 0,
        }
    }

    /// Enable periodic straggler injection.
    pub fn with_stragglers(mut self, every: u64, factor: f64) -> Self {
        self.straggle_every = every;
        self.straggle_factor = factor;
        self
    }
}

impl KernelExecutor for SimExecutor {
    fn estimate_us(&self, k: &KernelDesc) -> f64 {
        self.cm.profile(k, &self.cfg).duration_us
    }

    fn execute(&mut self, sk: &SuperKernel) -> f64 {
        self.counter += 1;
        let base = self.cm.profile(&sk.kernel, &self.cfg).duration_us;
        if self.straggle_every > 0 && self.counter % self.straggle_every == 0 {
            base * self.straggle_factor
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::StreamId;

    fn jit() -> JitCompiler<SimExecutor> {
        JitCompiler::new(JitConfig::default(), SimExecutor::v100())
    }

    fn req(stream: u32, m: u32, slo_us: f64) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(m, 512, 64), slo_us)
    }

    #[test]
    fn single_op_completes_and_meets_slo() {
        let mut j = jit();
        let done = j.run_trace(vec![(0.0, req(0, 128, 50_000.0))]);
        assert_eq!(done.len(), 1);
        assert!(done[0].met_deadline);
        assert_eq!(j.stats.slo_attainment(), 1.0);
        assert_eq!(j.stats.launches, 1);
    }

    #[test]
    fn concurrent_streams_coalesce() {
        let mut j = jit();
        let ops: Vec<(f64, DispatchRequest)> =
            (0..4).map(|s| (0.0, req(s, 128, 50_000.0))).collect();
        let done = j.run_trace(ops);
        assert_eq!(done.len(), 4);
        assert_eq!(j.stats.launches, 1, "4 compatible ops must pack into 1");
        assert_eq!(j.stats.mean_pack(), 4.0);
        assert!(done.iter().all(|c| c.pack_size == 4));
    }

    #[test]
    fn staggering_waits_for_latecomers() {
        // op A arrives at t=0 with big slack; B arrives 300µs later with a
        // compatible shape: the JIT should launch them TOGETHER
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (300.0, req(1, 128, 50_000.0)),
        ]);
        assert_eq!(j.stats.launches, 1, "staggering must coalesce A with B");
        assert!(done.iter().all(|c| c.pack_size == 2));
    }

    #[test]
    fn tight_slo_launches_alone() {
        // op A has almost no slack: it cannot wait for op B
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 700.0)),
            (1_500.0, req(1, 128, 50_000.0)),
        ]);
        assert_eq!(j.stats.launches, 2);
        assert!(done[0].pack_size == 1);
        assert!(done[0].met_deadline, "latency {}", done[0].latency_us());
    }

    #[test]
    fn program_order_within_stream_is_preserved() {
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (0.0, req(0, 128, 50_000.0)),
            (0.0, req(0, 128, 50_000.0)),
        ]);
        // same stream: sequential, 3 launches, completion order = seq order
        assert_eq!(j.stats.launches, 3);
        let seqs: Vec<u64> = done.iter().map(|c| c.op.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn padding_efficiency_tracked() {
        let mut j = jit();
        // 100x500x60 pads to 128x512x64
        j.run_trace(vec![(
            0.0,
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(100, 500, 60), 10_000.0),
        )]);
        let eff = j.stats.pack_efficiency();
        assert!(eff > 0.5 && eff < 1.0, "eff={eff}");
    }

    #[test]
    fn evictions_counted_and_completed() {
        let mut j = JitCompiler::new(
            JitConfig::default(),
            SimExecutor::v100().with_stragglers(2, 10.0),
        );
        let done = j.run_trace(vec![
            (0.0, req(0, 2048, 1e9)),
            (10_000.0, req(1, 2048, 1e9)),
        ]);
        assert_eq!(done.len(), 2);
        assert_eq!(j.stats.evictions, 1);
        assert!(done.iter().any(|c| c.evicted));
    }

    #[test]
    fn slo_misses_recorded_under_overload() {
        let mut j = jit();
        // 64 big ops with impossible 100µs SLOs
        let ops: Vec<(f64, DispatchRequest)> = (0..64)
            .map(|s| (0.0, req(s % 8, 4096, 100.0)))
            .collect();
        let done = j.run_trace(ops);
        assert_eq!(done.len(), 64);
        assert!(j.stats.slo_misses > 0);
        assert!(j.stats.slo_attainment() < 1.0);
    }

    #[test]
    fn trace_clock_monotone() {
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (5_000.0, req(1, 128, 50_000.0)),
            (9_000.0, req(2, 128, 50_000.0)),
        ]);
        let mut last = 0.0;
        for c in &done {
            assert!(c.done_us >= last);
            last = c.done_us;
        }
    }
}
