//! The JIT issue loop: window + scheduler + coalescer + executor.
//!
//! `JitCompiler` is the core shared by every deployment mode, driven
//! through exactly two surfaces:
//!
//! * **synchronous** (`run_trace`/`pump`, kernel-level benches and the
//!   simulator executor): replay a timed op trace, executing each launch
//!   inline and advancing a virtual clock through scheduler decisions;
//! * **ticketed** (`issue_ready` → `run_issued`/external execution →
//!   `finish_launch`): the serving engine's drive surface
//!   ([`crate::serve::engine::Engine`] is the ONE caller) — packs issue
//!   as tickets, execute on a device timeline, inline on the driver
//!   thread, or on pool workers, and report back with their outcome;
//!   several superkernels (for different models) run in parallel.
//!
//! The executor is abstract: [`KernelExecutor`] is the payload-free
//! kernel-level backend (V100 cost model, PJRT superkernels);
//! [`PackExecutor`] generalizes it to packs carrying an attached request
//! payload `P` (the serving layer attaches request rows and executes the
//! pack as one padded model batch). Every `KernelExecutor` is a
//! `PackExecutor<()>` for free.
//!
//! Every `estimate_*` number the scheduler consumes here (hold/evict
//! decisions, in-flight backlog pricing) comes from the executor's cost
//! model, which since the [`crate::estimate`] refactor is the tiered
//! Measured/Tuned/Prior estimator for serving
//! ([`crate::serve::server::ServeExecutor`]) and the analytic Prior tier
//! ([`crate::estimate::prior`]) for the kernel-level simulator backend —
//! the JIT itself never constructs an EWMA or queries the GPU cost model
//! directly for pricing.
//!
//! # Straggler-eviction accounting contract (§5.2)
//!
//! The two drive modes charge stragglers differently, **on purpose**:
//!
//! * **Synchronous** (`launch_sync`, the kernel-level `run_trace`/`pump`
//!   mode): eviction happens *inside* the simulated launch. The pack is
//!   charged the straggler time up to the eviction trigger
//!   ([`crate::compiler::scheduler::Scheduler::eviction_charge_us`],
//!   identical to the `should_evict` threshold) **plus a clean re-run at
//!   estimate** — in a simulated world the killed work really must be
//!   redone before the ops can complete.
//! * **Ticketed** (`finish_launch` — every serving mode, wall or virtual,
//!   since the unified engine): the reported duration is what it is. By
//!   the time the driver reports back, the work has already happened (or,
//!   on a virtual device timeline, has already been modeled end to end),
//!   so an over-threshold launch is *counted* as an eviction (stats +
//!   completion flags, feeding the same §5.2 telemetry) but is charged
//!   only its reported time — charging a retry would double-bill work
//!   that was never re-executed.
//!
//! Both paths are pinned by tests (`sync_eviction_charges_straggler_plus_retry`,
//! `async_eviction_counts_but_never_recharges`).

use std::collections::HashMap;

use crate::compiler::coalescer::{same_stream_rows, Coalescer, SuperKernel};
use crate::compiler::ir::{DispatchRequest, OpId, TensorOp};
use crate::compiler::scheduler::{Decision, Policy, Scheduler};
use crate::compiler::window::Window;
use crate::gpu::kernel::KernelDesc;
use crate::util::stats::LatencyHist;

/// Backend abstraction: estimate and execute batched kernels.
pub trait KernelExecutor {
    /// Estimated execution time of a batched kernel, µs (scheduler input).
    fn estimate_us(&self, k: &KernelDesc) -> f64;
    /// Execute a superkernel; returns the actual wall/virtual duration, µs.
    fn execute(&mut self, sk: &SuperKernel) -> f64;
}

/// One pack member handed to a payload-aware executor: the scheduled op
/// plus the payload attached at submission.
pub struct PackMember<'a, P> {
    /// The scheduled op.
    pub op: &'a TensorOp,
    /// The attached request payload.
    pub payload: &'a P,
}

/// Outcome of executing one pack.
#[derive(Debug, Clone)]
pub struct PackRun {
    /// Measured (or charged) execution time, µs.
    pub duration_us: f64,
    /// Problems/batch capacity actually executed after padding
    /// (≥ pack size).
    pub executed: u32,
    /// False when the backend failed; member ops complete as dropped.
    pub ok: bool,
    /// Device class that executed the launch (0 = the fleet reference /
    /// single-device drive modes). Keys the Measured tier of the tiered
    /// estimator ([`crate::estimate`]) so heterogeneous workers never
    /// pollute each other's learned durations.
    pub device_class: u32,
}

/// Payload-aware pack execution. Estimation sees the member ops (group +
/// count) so backends can price the *padded* variant that will actually
/// run; execution sees the payloads. Implemented for every
/// [`KernelExecutor`] with `P = ()`.
pub trait PackExecutor<P> {
    /// Estimated execution time for a pack of these members, µs.
    fn estimate_pack_us(&self, k: &KernelDesc, ops: &[&TensorOp]) -> f64;
    /// Execute a pack with its payloads.
    fn execute_pack(&mut self, sk: &SuperKernel, members: &[PackMember<'_, P>]) -> PackRun;
    /// Fold a finished launch back into learned estimates. Called once per
    /// launch by the JIT (both drive modes), never by `execute_pack`.
    fn observe_pack(&mut self, _sk: &SuperKernel, _ops: &[&TensorOp], _run: &PackRun) {}
    /// Generation counter of the estimates behind
    /// [`PackExecutor::estimate_pack_us`] — the incremental scheduler
    /// reuses a cached pack estimate until this changes (the tiered
    /// estimator bumps it on tier transitions; see
    /// `crate::estimate::TieredEstimator::generation`). Estimators whose
    /// answers never change generation keep the default constant.
    fn estimate_generation(&self) -> u64 {
        0
    }
}

impl<E: KernelExecutor> PackExecutor<()> for E {
    fn estimate_pack_us(&self, k: &KernelDesc, _ops: &[&TensorOp]) -> f64 {
        self.estimate_us(k)
    }

    fn execute_pack(&mut self, sk: &SuperKernel, _members: &[PackMember<'_, ()>]) -> PackRun {
        PackRun {
            duration_us: self.execute(&sk.kernel_for_exec()),
            executed: sk.kernel.problems,
            ok: true,
            device_class: 0,
        }
    }
}

/// JIT configuration.
#[derive(Debug, Clone)]
pub struct JitConfig {
    /// Scheduling policy.
    pub policy: Policy,
    /// Packing rules.
    pub coalescer: Coalescer,
    /// Issue-window capacity (backpressure bound).
    pub window_capacity: usize,
    /// Per-launch JIT bookkeeping overhead, µs (measured by perf_hotpath);
    /// charged in the synchronous drive mode only — in real time it is
    /// part of the measured wall clock.
    pub packing_overhead_us: f64,
}

impl Default for JitConfig {
    fn default() -> Self {
        JitConfig {
            policy: Policy::default(),
            coalescer: Coalescer::default(),
            window_capacity: 1024,
            packing_overhead_us: 2.0,
        }
    }
}

/// Completion record for one op.
#[derive(Debug, Clone)]
pub struct OpCompletion {
    /// The op.
    pub op: TensorOp,
    /// Issue time, µs.
    pub issue_us: f64,
    /// Completion time, µs.
    pub done_us: f64,
    /// Problems in the superkernel it rode in.
    pub pack_size: usize,
    /// True if the op met its deadline.
    pub met_deadline: bool,
    /// True if the launch was evicted once as a straggler and retried.
    pub evicted: bool,
    /// True if the backend execution failed (the op was dropped, not
    /// served; never counted as an SLO hit).
    pub failed: bool,
}

impl OpCompletion {
    /// End-to-end latency, µs.
    pub fn latency_us(&self) -> f64 {
        self.done_us - self.op.arrival_us
    }
}

/// Aggregate JIT statistics.
#[derive(Debug, Clone, Default)]
pub struct JitStats {
    /// Superkernels launched.
    pub launches: u64,
    /// Ops completed (including failed ones).
    pub ops: u64,
    /// Ops whose backend execution failed.
    pub failed_ops: u64,
    /// Useful FLOPs (pre-padding).
    pub useful_flops: f64,
    /// Launched FLOPs (incl. padding).
    pub launched_flops: f64,
    /// Device-busy virtual time, µs.
    pub busy_us: f64,
    /// Deadline hits.
    pub slo_hits: u64,
    /// Deadline misses.
    pub slo_misses: u64,
    /// Straggler evictions (§5.2).
    pub evictions: u64,
    /// Pack rows that shared a launch with an earlier row of the same
    /// stream — the stream-prefix coalescing the independence flag buys.
    pub same_stream_rows: u64,
    /// Plans checked by the machine verifier ([`crate::analysis::plan`])
    /// — non-zero whenever [`Policy::verify_plans`] is on.
    ///
    /// [`Policy::verify_plans`]: crate::compiler::scheduler::Policy::verify_plans
    pub plan_checks: u64,
    /// Violations the verifier found. Under `debug_assertions` a
    /// violation panics instead (fail-stop in tests); in release runs
    /// this counter is the fail-open record BENCH_9 asserts is zero.
    pub plan_violations: u64,
    /// Per-decide latency histogram, **nanoseconds**. Populated only when
    /// [`JitCompiler::decide_clock`] is set (the serve layer injects a
    /// monotonic clock; virtual-time deployments leave it `None` so the
    /// pure compiler layer never reads wall time itself).
    pub decide_ns: LatencyHist,
    /// Buckets whose cached packs were reused as-is across decides
    /// (clean buckets under the incremental scheduler's delta contract).
    pub buckets_reused: u64,
    /// Buckets re-packed and re-priced because a window delta or an
    /// estimator generation bump dirtied them.
    pub buckets_repacked: u64,
}

impl JitStats {
    /// Mean problems per launch (VLIW word occupancy).
    pub fn mean_pack(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.ops as f64 / self.launches as f64
        }
    }

    /// FLOP padding efficiency.
    pub fn pack_efficiency(&self) -> f64 {
        if self.launched_flops <= 0.0 {
            1.0
        } else {
            self.useful_flops / self.launched_flops
        }
    }

    /// SLO attainment fraction.
    pub fn slo_attainment(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }
}

/// Per-launch record surfaced to the serving metrics.
#[derive(Debug, Clone)]
pub struct LaunchRecord {
    /// Useful problems in the pack.
    pub pack_size: u32,
    /// Executed (padded) problems/batch.
    pub executed: u32,
    /// Charged/measured duration, µs.
    pub duration_us: f64,
    /// Backend execution succeeded.
    pub ok: bool,
    /// Rows sharing this launch with an earlier row of the same stream
    /// (stream-prefix coalescing; 0 = all members from distinct streams).
    pub same_stream_rows: u32,
}

/// An issued-but-unfinished launch in the concurrent drive mode.
pub struct PendingLaunch {
    /// Handle to pass back to [`JitCompiler::finish_launch`].
    pub ticket: u64,
    /// The pack to execute (ops in EDF order).
    pub pack: SuperKernel,
    /// Scheduler estimate at issue, µs.
    pub est_us: f64,
    /// Issue time, µs.
    pub issue_us: f64,
}

struct IssuedPack {
    pack: SuperKernel,
    issue_us: f64,
    est_us: f64,
}

/// The OoO VLIW JIT compiler instance, generic over the executor and an
/// attached per-op request payload `P` (rows for the serving layer, `()`
/// for kernel-level deployments).
pub struct JitCompiler<E, P = ()> {
    /// Issue window.
    pub window: Window,
    scheduler: Scheduler,
    executor: E,
    cfg: JitConfig,
    payloads: HashMap<OpId, P>,
    pending: HashMap<u64, IssuedPack>,
    next_ticket: u64,
    launch_log: Vec<LaunchRecord>,
    /// Virtual/wall clock, µs.
    pub now_us: f64,
    /// Aggregate stats.
    pub stats: JitStats,
    /// Optional monotonic clock (nanoseconds) used to time each `decide`
    /// into [`JitStats::decide_ns`]. A plain fn pointer keeps the compiler
    /// layer pure — the serve layer injects one backed by `Instant`;
    /// virtual-time tests and benches leave it `None` (no timing cost).
    pub decide_clock: Option<fn() -> u64>,
}

impl<E, P> JitCompiler<E, P> {
    /// New JIT with an attached-payload type.
    pub fn with_payloads(cfg: JitConfig, executor: E) -> Self {
        JitCompiler {
            window: Window::new(cfg.window_capacity),
            scheduler: Scheduler::new(cfg.policy.clone(), cfg.coalescer.clone()),
            executor,
            cfg,
            payloads: HashMap::new(),
            pending: HashMap::new(),
            next_ticket: 0,
            launch_log: Vec::new(),
            now_us: 0.0,
            stats: JitStats::default(),
            decide_clock: None,
        }
    }

    /// Borrow the executor.
    pub fn executor(&self) -> &E {
        &self.executor
    }

    /// Mutably borrow the executor.
    pub fn executor_mut(&mut self) -> &mut E {
        &mut self.executor
    }

    /// Advance the clock to (at least) `now_us` — the real-time drivers'
    /// wall-clock feed. Never moves the clock backwards.
    pub fn advance_to(&mut self, now_us: f64) {
        self.now_us = self.now_us.max(now_us);
    }

    /// Launches issued but not yet finished (concurrent drive mode).
    pub fn inflight_launches(&self) -> usize {
        self.pending.len()
    }

    /// Effective per-launch pack-size cap for a group (the coalescer's
    /// group cap bounded by `max_problems`) — how many queued ops one
    /// launch can drain, the admission layer's queue-pricing divisor.
    pub fn pack_cap(&self, group: u64) -> usize {
        self.cfg.coalescer.cap_of(group)
    }

    /// Summed scheduler estimates of the issued-but-unfinished launches of
    /// a coalescing group — the admission layer's in-flight drain term.
    /// Priced *per launch* (several small launches keep their per-launch
    /// fixed overheads; one big pack is one estimate), with the execution
    /// time already elapsed subtracted from the `concurrency` *oldest*
    /// launches only (clamped at zero: a straggler past its estimate
    /// contributes nothing rather than a negative drain). `concurrency`
    /// is how many of the group's launches can actually be executing at
    /// once — the pool workers serving it (its placement replica count; 1
    /// for the single-device modes). Launches behind them sit queued, not
    /// executing, so wall time since their issue must NOT be credited —
    /// doing so for every launch re-opens the doomed-admission hole this
    /// term exists to close.
    pub fn inflight_group_est_us(&self, group: u64, concurrency: u32) -> f64 {
        let mut launches: Vec<(f64, u64, f64)> = self
            .pending
            .iter()
            .filter(|(_, p)| {
                p.pack
                    .ops
                    .first()
                    .and_then(|id| self.window.get(*id))
                    .is_some_and(|op| op.group == group)
            })
            .map(|(ticket, p)| (p.issue_us, *ticket, p.est_us))
            .collect();
        // oldest first; ticket tie-break keeps the sum order deterministic
        launches.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .expect("NaN issue time")
                .then(a.1.cmp(&b.1))
        });
        let executing = concurrency.max(1) as usize;
        launches
            .iter()
            .enumerate()
            .map(|(i, (issue_us, _, est_us))| {
                if i < executing {
                    (est_us - (self.now_us - issue_us).max(0.0)).max(0.0)
                } else {
                    *est_us
                }
            })
            .sum()
    }

    /// Re-price an issued-but-unfinished launch's estimate. The placed
    /// drivers call this once routing is known: the issue-time estimate
    /// was priced on the group's *primary* device class, but straggler
    /// eviction (and the in-flight drain term) must judge the launch
    /// against the device that actually runs it — a k80-routed replica
    /// launch legitimately takes ~4x the v100 estimate and is not a
    /// straggler. No-op for unknown tickets.
    pub fn reprice_pending(&mut self, ticket: u64, est_us: f64) {
        if let Some(p) = self.pending.get_mut(&ticket) {
            p.est_us = est_us;
        }
    }

    /// Drain the per-launch log accumulated since the last call.
    pub fn take_launches(&mut self) -> Vec<LaunchRecord> {
        std::mem::take(&mut self.launch_log)
    }

    /// Payloads attached to the given ops (issue order preserved).
    pub fn payloads_of(&self, ops: &[OpId]) -> Vec<&P> {
        ops.iter()
            .map(|id| self.payloads.get(id).expect("payload present"))
            .collect()
    }
}

impl<E> JitCompiler<E> {
    /// New payload-free JIT over an executor.
    pub fn new(cfg: JitConfig, executor: E) -> Self {
        Self::with_payloads(cfg, executor)
    }
}

impl<E, P> JitCompiler<E, P>
where
    E: PackExecutor<P>,
{
    /// Submit an op at the current clock. Returns None on backpressure.
    pub fn submit(&mut self, req: DispatchRequest) -> Option<OpId>
    where
        P: Default,
    {
        let now = self.now_us;
        self.submit_at(req, now, P::default())
    }

    /// Submit an op with a payload at the current clock.
    pub fn submit_with(&mut self, req: DispatchRequest, payload: P) -> Option<OpId> {
        let now = self.now_us;
        self.submit_at(req, now, payload)
    }

    /// Submit an op with an explicit arrival time (≤ the current clock):
    /// the serving replay driver admits requests whose true arrival
    /// precedes the instant the device freed up, and latency/deadline
    /// accounting must use the true arrival.
    pub fn submit_at(
        &mut self,
        req: DispatchRequest,
        arrival_us: f64,
        payload: P,
    ) -> Option<OpId> {
        let id = self.window.submit(req, arrival_us)?;
        self.payloads.insert(id, payload);
        Some(id)
    }

    fn decide(&mut self) -> Decision {
        let t0 = self.decide_clock.map(|clock| clock());
        let d = {
            let Self { window, scheduler, executor, now_us, .. } = self;
            let gen = executor.estimate_generation();
            let ex: &E = executor;
            scheduler.decide(window, *now_us, gen, |k, ops| ex.estimate_pack_us(k, ops))
        };
        self.stats.buckets_reused = self.scheduler.buckets_reused();
        self.stats.buckets_repacked = self.scheduler.buckets_repacked();
        if let (Some(clock), Some(t0)) = (self.decide_clock, t0) {
            self.stats.decide_ns.record_us(clock().saturating_sub(t0) as f64);
        }
        d
    }

    /// Drive the loop at the current instant: launch everything the policy
    /// allows, executing synchronously. Returns completions and the time
    /// the next decision is due (None = window drained or all blocked).
    pub fn pump(&mut self) -> (Vec<OpCompletion>, Option<f64>) {
        let mut out = Vec::new();
        loop {
            match self.decide() {
                Decision::Idle => return (out, None),
                Decision::Wait { until_us } => return (out, Some(until_us)),
                Decision::Launch(pack) => {
                    out.extend(self.launch_sync(pack));
                }
            }
        }
    }

    /// Issue (without executing) every pack the policy allows right now —
    /// the concurrent drive mode's planning step. Issued packs are
    /// in-flight until [`JitCompiler::finish_launch`]; their streams keep
    /// feeding successor ops into later packs (issue-order readiness), so
    /// independent superkernels pipeline across worker threads.
    pub fn issue_ready(&mut self) -> (Vec<PendingLaunch>, Option<f64>) {
        let mut out = Vec::new();
        loop {
            match self.decide() {
                Decision::Idle => return (out, None),
                Decision::Wait { until_us } => return (out, Some(until_us)),
                Decision::Launch(pack) => {
                    if self.cfg.policy.verify_plans {
                        self.verify_plan(&pack);
                    }
                    self.window.issue(&pack.ops);
                    let est = {
                        let members = Self::members(&self.window, &pack);
                        self.executor.estimate_pack_us(&pack.kernel, &members)
                    };
                    let ticket = self.next_ticket;
                    self.next_ticket += 1;
                    let issue_us = self.now_us;
                    self.pending.insert(
                        ticket,
                        IssuedPack {
                            pack: pack.clone(),
                            issue_us,
                            est_us: est,
                        },
                    );
                    out.push(PendingLaunch {
                        ticket,
                        pack,
                        est_us: est,
                        issue_us,
                    });
                }
            }
        }
    }

    /// Execute an issued launch inline on the JIT's own executor (the
    /// single-threaded real-time driver). Pair with
    /// [`JitCompiler::finish_launch`] using the measured wall time.
    pub fn run_issued(&mut self, ticket: u64) -> PackRun {
        let pack = self
            .pending
            .get(&ticket)
            .expect("unknown launch ticket")
            .pack
            .clone();
        let members = Self::members(&self.window, &pack);
        let pm: Vec<PackMember<'_, P>> = members
            .iter()
            .map(|op| PackMember {
                op: *op,
                payload: self.payloads.get(&op.id).expect("payload present"),
            })
            .collect();
        self.executor.execute_pack(&pack, &pm)
    }

    /// Complete an issued launch with its outcome, observed at wall time
    /// `done_us`. Applies straggler-eviction accounting (no retry: in real
    /// time the work has already happened) and returns the completions.
    pub fn finish_launch(
        &mut self,
        ticket: u64,
        done_us: f64,
        run: PackRun,
    ) -> Vec<OpCompletion> {
        let issued = self.pending.remove(&ticket).expect("unknown launch ticket");
        self.advance_to(done_us);
        let pack_class = {
            let members = Self::members(&self.window, &issued.pack);
            self.executor.observe_pack(&issued.pack, &members, &run);
            members.first().map(|op| op.class).unwrap_or_default()
        };
        // class-aware straggler threshold: best-effort launches trip on
        // the tighter scaled factor (eviction-order leg of the class
        // contract), so a degraded device sheds batch work first
        let evicted = run.ok
            && self.scheduler.should_evict_class(
                pack_class,
                issued.issue_us,
                issued.est_us,
                issued.issue_us + run.duration_us,
            );
        if evicted {
            self.stats.evictions += 1;
        }
        self.record_launch(&issued.pack, &run);
        self.complete_pack(&issued.pack, issued.issue_us, done_us, &run, evicted)
    }

    /// Execute one superkernel synchronously, advancing the clock by its
    /// duration (+ packing overhead), applying straggler eviction (§5.2):
    /// if the actual runtime blows past the eviction threshold, the launch
    /// is charged the straggler time up to the trigger plus a clean re-run
    /// at estimate (counted in stats).
    fn launch_sync(&mut self, pack: SuperKernel) -> Vec<OpCompletion> {
        if self.cfg.policy.verify_plans {
            self.verify_plan(&pack);
        }
        self.window.issue(&pack.ops);
        let issue_us = self.now_us;
        let (est, pack_class, mut run) = {
            let members = Self::members(&self.window, &pack);
            let est = self.executor.estimate_pack_us(&pack.kernel, &members);
            let pack_class = members.first().map(|op| op.class).unwrap_or_default();
            let pm: Vec<PackMember<'_, P>> = members
                .iter()
                .map(|op| PackMember {
                    op: *op,
                    payload: self.payloads.get(&op.id).expect("payload present"),
                })
                .collect();
            let run = self.executor.execute_pack(&pack, &pm);
            drop(pm);
            self.executor.observe_pack(&pack, &members, &run);
            (est, pack_class, run)
        };
        let mut evicted = false;
        if run.ok
            && self
                .scheduler
                .should_evict_class(pack_class, issue_us, est, issue_us + run.duration_us)
        {
            // evict + retry once: pay the straggler time up to the eviction
            // trigger (the pack class's own threshold), then a clean re-run
            // at estimate
            self.stats.evictions += 1;
            evicted = true;
            run.duration_us = self.scheduler.eviction_charge_us_class(pack_class, est) + est;
        }
        run.duration_us += self.cfg.packing_overhead_us;
        self.now_us += run.duration_us;
        self.record_launch(&pack, &run);
        let done_us = self.now_us;
        self.complete_pack(&pack, issue_us, done_us, &run, evicted)
    }

    /// Machine-verify a plan before issue (PLAN001–PLAN007, see
    /// [`crate::analysis::plan`]). Fail-stop under `debug_assertions` —
    /// the test suites must never issue a hazardous superkernel —
    /// fail-open but counted in release, so a production run keeps
    /// serving while `plan_violations` records the regression.
    fn verify_plan(&mut self, pack: &SuperKernel) {
        self.stats.plan_checks += 1;
        let live: Vec<&SuperKernel> = self.pending.values().map(|p| &p.pack).collect();
        let vs = crate::analysis::plan::verify_pack(&self.window, &self.cfg.coalescer, pack, &live);
        if !vs.is_empty() {
            self.stats.plan_violations += vs.len() as u64;
            if cfg!(debug_assertions) {
                let lines: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
                panic!("plan verifier rejected superkernel:\n{}", lines.join("\n"));
            }
        }
    }

    fn members<'a>(window: &'a Window, pack: &SuperKernel) -> Vec<&'a TensorOp> {
        pack.ops
            .iter()
            .map(|id| window.get(*id).expect("pack member in window"))
            .collect()
    }

    fn record_launch(&mut self, pack: &SuperKernel, run: &PackRun) {
        // members are still in the window at record time (issued, not yet
        // completed), so the pack's stream composition is observable here
        let same_stream = {
            let members = Self::members(&self.window, pack);
            same_stream_rows(&members) as u32
        };
        self.stats.launches += 1;
        self.stats.useful_flops += pack.useful_flops;
        self.stats.same_stream_rows += same_stream as u64;
        let executed = run.executed.max(pack.ops.len() as u32);
        self.stats.launched_flops += pack.class.kernel(executed).flops();
        self.stats.busy_us += run.duration_us;
        self.launch_log.push(LaunchRecord {
            pack_size: pack.ops.len() as u32,
            executed,
            duration_us: run.duration_us,
            ok: run.ok,
            same_stream_rows: same_stream,
        });
    }

    fn complete_pack(
        &mut self,
        pack: &SuperKernel,
        issue_us: f64,
        done_us: f64,
        run: &PackRun,
        evicted: bool,
    ) -> Vec<OpCompletion> {
        pack.ops
            .iter()
            .map(|id| {
                let op = self.window.complete(*id);
                self.payloads.remove(id);
                let met = run.ok && done_us <= op.deadline_us;
                if !run.ok {
                    self.stats.failed_ops += 1;
                } else if met {
                    self.stats.slo_hits += 1;
                } else {
                    self.stats.slo_misses += 1;
                }
                self.stats.ops += 1;
                OpCompletion {
                    op,
                    issue_us,
                    done_us,
                    pack_size: pack.ops.len(),
                    met_deadline: met,
                    evicted,
                    failed: !run.ok,
                }
            })
            .collect()
    }

    /// Replay a timed trace in virtual time. `ops` must be sorted by
    /// arrival. Returns all completions.
    pub fn run_trace(&mut self, ops: Vec<(f64, DispatchRequest)>) -> Vec<OpCompletion>
    where
        P: Default,
    {
        let mut out = Vec::new();
        let mut next = 0usize;
        loop {
            // admit everything that has arrived
            while next < ops.len() && ops[next].0 <= self.now_us + 1e-9 {
                let (_, req) = ops[next].clone();
                if self.submit(req).is_none() {
                    // backpressure in virtual time: let the device catch up
                    break;
                }
                next += 1;
            }
            let (done, wake) = self.pump();
            out.extend(done);
            let next_arrival = ops.get(next).map(|(t, _)| *t);
            match (wake, next_arrival) {
                (None, None) if self.window.is_empty() => break,
                (None, None) => {
                    // all blocked with nothing arriving: should not happen
                    // (blocked implies in-flight, and launch is synchronous)
                    unreachable!("deadlocked window");
                }
                (None, Some(t)) => self.now_us = self.now_us.max(t),
                (Some(w), None) => self.now_us = self.now_us.max(w),
                (Some(w), Some(t)) => self.now_us = self.now_us.max(w.min(t)),
            }
        }
        out
    }
}

impl SuperKernel {
    /// The kernel actually executed (identical; hook for future fusion).
    fn kernel_for_exec(&self) -> SuperKernel {
        self.clone()
    }
}

/// Simulator-backed executor: durations from the V100 cost model, with an
/// optional deterministic straggler injector for eviction tests.
pub struct SimExecutor {
    /// Cost model.
    pub cm: crate::gpu::cost::CostModel,
    /// Launch config used for superkernels.
    pub cfg: crate::gpu::kernel::LaunchConfig,
    /// Every `straggle_every`-th launch runs `straggle_factor×` slower
    /// (0 = never).
    pub straggle_every: u64,
    /// Straggler slowdown factor.
    pub straggle_factor: f64,
    counter: u64,
}

impl SimExecutor {
    /// V100-backed executor with the greedy config.
    pub fn v100() -> Self {
        SimExecutor {
            cm: crate::gpu::cost::CostModel::v100(),
            cfg: crate::gpu::kernel::LaunchConfig::greedy(),
            straggle_every: 0,
            straggle_factor: 5.0,
            counter: 0,
        }
    }

    /// Enable periodic straggler injection.
    pub fn with_stragglers(mut self, every: u64, factor: f64) -> Self {
        self.straggle_every = every;
        self.straggle_factor = factor;
        self
    }
}

impl KernelExecutor for SimExecutor {
    fn estimate_us(&self, k: &KernelDesc) -> f64 {
        // pricing goes through the estimate subsystem's Prior tier — the
        // one sanctioned analytic-cost path for launch estimates; the
        // `execute` below keeps using the cost model directly because it
        // *simulates* the hardware, it doesn't price it
        crate::estimate::prior::analytic_us(&self.cm, &self.cfg, k)
    }

    fn execute(&mut self, sk: &SuperKernel) -> f64 {
        self.counter += 1;
        let base = self.cm.profile(&sk.kernel, &self.cfg).duration_us;
        if self.straggle_every > 0 && self.counter % self.straggle_every == 0 {
            base * self.straggle_factor
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::StreamId;

    fn jit() -> JitCompiler<SimExecutor> {
        JitCompiler::new(JitConfig::default(), SimExecutor::v100())
    }

    fn req(stream: u32, m: u32, slo_us: f64) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(m, 512, 64), slo_us)
    }

    #[test]
    fn single_op_completes_and_meets_slo() {
        let mut j = jit();
        let done = j.run_trace(vec![(0.0, req(0, 128, 50_000.0))]);
        assert_eq!(done.len(), 1);
        assert!(done[0].met_deadline);
        assert_eq!(j.stats.slo_attainment(), 1.0);
        assert_eq!(j.stats.launches, 1);
    }

    #[test]
    fn concurrent_streams_coalesce() {
        let mut j = jit();
        let ops: Vec<(f64, DispatchRequest)> =
            (0..4).map(|s| (0.0, req(s, 128, 50_000.0))).collect();
        let done = j.run_trace(ops);
        assert_eq!(done.len(), 4);
        assert_eq!(j.stats.launches, 1, "4 compatible ops must pack into 1");
        assert_eq!(j.stats.mean_pack(), 4.0);
        assert!(done.iter().all(|c| c.pack_size == 4));
    }

    #[test]
    fn staggering_waits_for_latecomers() {
        // op A arrives at t=0 with big slack; B arrives 300µs later with a
        // compatible shape: the JIT should launch them TOGETHER
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (300.0, req(1, 128, 50_000.0)),
        ]);
        assert_eq!(j.stats.launches, 1, "staggering must coalesce A with B");
        assert!(done.iter().all(|c| c.pack_size == 2));
    }

    #[test]
    fn tight_slo_launches_alone() {
        // op A has almost no slack: it cannot wait for op B
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 700.0)),
            (1_500.0, req(1, 128, 50_000.0)),
        ]);
        assert_eq!(j.stats.launches, 2);
        assert!(done[0].pack_size == 1);
        assert!(done[0].met_deadline, "latency {}", done[0].latency_us());
    }

    #[test]
    fn program_order_within_stream_is_preserved() {
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (0.0, req(0, 128, 50_000.0)),
            (0.0, req(0, 128, 50_000.0)),
        ]);
        // same stream: sequential issue, 3 launches (a pack never holds
        // two ops of one stream), completion order = seq order
        assert_eq!(j.stats.launches, 3);
        let seqs: Vec<u64> = done.iter().map(|c| c.op.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn padding_efficiency_tracked() {
        let mut j = jit();
        // 100x500x60 pads to 128x512x64
        j.run_trace(vec![(
            0.0,
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(100, 500, 60), 10_000.0),
        )]);
        let eff = j.stats.pack_efficiency();
        assert!(eff > 0.5 && eff < 1.0, "eff={eff}");
    }

    #[test]
    fn evictions_counted_and_completed() {
        let mut j = JitCompiler::new(
            JitConfig::default(),
            SimExecutor::v100().with_stragglers(2, 10.0),
        );
        let done = j.run_trace(vec![
            (0.0, req(0, 2048, 1e9)),
            (10_000.0, req(1, 2048, 1e9)),
        ]);
        assert_eq!(done.len(), 2);
        assert_eq!(j.stats.evictions, 1);
        assert!(done.iter().any(|c| c.evicted));
    }

    #[test]
    fn single_stream_independent_burst_coalesces_into_one_launch() {
        // 8 independent requests from ONE stream: the ready prefix lets the
        // whole burst ride a single superkernel (the paper's coalescing
        // opportunity, now available within a tenant's own queue)
        let mut j = jit();
        let ops: Vec<(f64, DispatchRequest)> = (0..8)
            .map(|_| (0.0, req(0, 128, 50_000.0).with_independent(true)))
            .collect();
        let done = j.run_trace(ops);
        assert_eq!(done.len(), 8);
        assert_eq!(j.stats.launches, 1, "one burst, one launch");
        assert_eq!(j.stats.mean_pack(), 8.0);
        assert_eq!(j.stats.same_stream_rows, 7, "7 rows share stream 0");
        assert!(done.iter().all(|c| c.pack_size == 8));
        let log = j.take_launches();
        assert_eq!(log[0].same_stream_rows, 7);
    }

    #[test]
    fn dependent_burst_still_serializes() {
        // without the independence flag the same burst keeps strict
        // per-stream issue order: one op per launch, zero same-stream rows
        let mut j = jit();
        let ops: Vec<(f64, DispatchRequest)> =
            (0..3).map(|_| (0.0, req(0, 128, 50_000.0))).collect();
        let done = j.run_trace(ops);
        assert_eq!(j.stats.launches, 3);
        assert_eq!(j.stats.same_stream_rows, 0);
        let seqs: Vec<u64> = done.iter().map(|c| c.op.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn sync_eviction_charges_straggler_plus_retry() {
        // the synchronous drive mode's accounting contract: an evicted
        // launch is charged up to the eviction trigger PLUS a clean re-run
        // at estimate (the simulated world must redo the killed work)
        let mut j = JitCompiler::new(
            JitConfig::default(),
            SimExecutor::v100().with_stragglers(1, 10.0), // every launch straggles
        );
        let done = j.run_trace(vec![(0.0, req(0, 2048, 1e9))]);
        assert_eq!(j.stats.evictions, 1);
        assert!(done[0].evicted);
        let est = SimExecutor::v100()
            .estimate_us(&KernelDesc::batched(1, 2048, 512, 64));
        // charge = eviction threshold (factor·est + slop) + retry at est,
        // plus the per-launch packing overhead
        let p = Policy::default();
        let expect =
            p.eviction_factor * est + p.eviction_slop_us + est + 2.0;
        let charged = done[0].done_us - done[0].issue_us;
        assert!(
            (charged - expect).abs() < 1e-6,
            "charged {charged} != contract {expect}"
        );
        assert!((j.stats.busy_us - expect).abs() < 1e-6);
    }

    #[test]
    fn async_eviction_counts_but_never_recharges() {
        // the real-time contract: the work already happened, so an
        // over-threshold launch is counted as an eviction but charged only
        // its measured duration — no simulated retry on top
        let mut j = eager_jit();
        assert!(j.submit(req(0, 2048, 1e9)).is_some());
        let (launches, _) = j.issue_ready();
        assert_eq!(launches.len(), 1);
        let l = &launches[0];
        let measured = l.est_us * 10.0; // well past the 3x + slop threshold
        let done_us = l.issue_us + measured;
        let completions = j.finish_launch(
            l.ticket,
            done_us,
            PackRun {
                duration_us: measured,
                executed: 1,
                ok: true,
                device_class: 0,
            },
        );
        assert_eq!(j.stats.evictions, 1);
        assert!(completions[0].evicted);
        assert_eq!(completions[0].done_us, done_us, "measured time stands");
        assert!(
            (j.stats.busy_us - measured).abs() < 1e-9,
            "busy {} must equal the measured duration, uncharged of any retry",
            j.stats.busy_us
        );
    }

    #[test]
    fn slo_misses_recorded_under_overload() {
        let mut j = jit();
        // 64 big ops with impossible 100µs SLOs
        let ops: Vec<(f64, DispatchRequest)> = (0..64)
            .map(|s| (0.0, req(s % 8, 4096, 100.0)))
            .collect();
        let done = j.run_trace(ops);
        assert_eq!(done.len(), 64);
        assert!(j.stats.slo_misses > 0);
        assert!(j.stats.slo_attainment() < 1.0);
    }

    #[test]
    fn trace_clock_monotone() {
        let mut j = jit();
        let done = j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (5_000.0, req(1, 128, 50_000.0)),
            (9_000.0, req(2, 128, 50_000.0)),
        ]);
        let mut last = 0.0;
        for c in &done {
            assert!(c.done_us >= last);
            last = c.done_us;
        }
    }

    #[test]
    fn launch_log_records_every_launch() {
        let mut j = jit();
        j.run_trace(vec![
            (0.0, req(0, 128, 50_000.0)),
            (0.0, req(1, 128, 50_000.0)),
        ]);
        let log = j.take_launches();
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].pack_size, 2);
        assert!(log[0].ok);
        assert!(log[0].duration_us > 0.0);
        assert!(j.take_launches().is_empty(), "log drains");
    }

    fn eager_jit() -> JitCompiler<SimExecutor> {
        // target_pack 1: every pack launches the moment it forms, so the
        // async tests don't depend on cost-model magnitudes
        let cfg = JitConfig {
            policy: Policy {
                target_pack: 1,
                ..Policy::default()
            },
            ..JitConfig::default()
        };
        JitCompiler::new(cfg, SimExecutor::v100())
    }

    #[test]
    fn async_drive_issues_and_finishes() {
        // the concurrent drive mode: issue tickets, execute "remotely",
        // finish with measured outcomes
        let mut j = eager_jit();
        assert!(j.submit(req(0, 128, 50_000.0)).is_some());
        assert!(j.submit(req(1, 2048, 50_000.0)).is_some()); // different class
        let (launches, _wake) = j.issue_ready();
        assert_eq!(launches.len(), 2, "both packs issue without waiting");
        assert_eq!(j.inflight_launches(), 2);
        // finish out of order with synthetic measured durations
        for l in launches.into_iter().rev() {
            let run = j.run_issued(l.ticket);
            assert!(run.ok);
            let done_us = l.issue_us + run.duration_us;
            let completions = j.finish_launch(l.ticket, done_us, run);
            assert_eq!(completions.len(), 1);
        }
        assert_eq!(j.inflight_launches(), 0);
        assert!(j.window.is_empty());
        assert_eq!(j.stats.launches, 2);
        assert_eq!(j.stats.ops, 2);
    }

    #[test]
    fn inflight_drain_subtracts_elapsed_execution() {
        // the admission pricing term: a launch halfway through its
        // estimate owes half its estimate, and a straggler past its
        // estimate owes zero (never negative)
        let mut j = eager_jit();
        assert!(j.submit(req(0, 128, 1e9)).is_some());
        let (launches, _) = j.issue_ready();
        assert_eq!(launches.len(), 1);
        let l = &launches[0];
        let est = l.est_us;
        assert!(est > 0.0);
        assert!(
            (j.inflight_group_est_us(0, 1) - est).abs() < 1e-9,
            "nothing elapsed"
        );
        j.advance_to(l.issue_us + est * 0.5);
        assert!(
            (j.inflight_group_est_us(0, 1) - est * 0.5).abs() < 1e-9,
            "half elapsed, half owed"
        );
        j.advance_to(l.issue_us + est * 4.0);
        assert_eq!(
            j.inflight_group_est_us(0, 1),
            0.0,
            "straggler owes zero, not negative"
        );
        let run = j.run_issued(l.ticket);
        j.finish_launch(l.ticket, l.issue_us + est * 4.0, run);
        assert_eq!(j.inflight_group_est_us(0, 1), 0.0);
    }

    #[test]
    fn inflight_drain_credits_elapsed_to_executing_launches_only() {
        // two launches but one serving worker (concurrency 1): wall time
        // elapses for both, yet only the oldest launch is executing — the
        // queued one still owes its full estimate. Crediting elapsed time
        // to queued launches would re-open the doomed-admission hole.
        let mut j = eager_jit();
        assert!(j.submit(req(0, 128, 1e9)).is_some());
        assert!(j.submit(req(1, 2048, 1e9)).is_some()); // different class
        let (launches, _) = j.issue_ready();
        assert_eq!(launches.len(), 2);
        let est0 = launches[0].est_us;
        let est1 = launches[1].est_us;
        let elapsed = est0 * 0.5;
        j.advance_to(launches[0].issue_us + elapsed);
        assert!(
            (j.inflight_group_est_us(0, 1) - (est0 - elapsed + est1)).abs() < 1e-9,
            "queued launch owes its full estimate"
        );
        // two workers: both launches execute concurrently, both credited
        assert!(
            (j.inflight_group_est_us(0, 2) - (est0 - elapsed + est1 - elapsed)).abs()
                < 1e-9
        );
    }

    #[test]
    fn async_drive_pipelines_one_stream() {
        // issue-order readiness: one stream's ops issue in sequence but
        // overlap in flight (the multi-worker launch stage's invariant)
        let mut j = eager_jit();
        assert!(j.submit(req(0, 128, 50_000.0)).is_some());
        assert!(j.submit(req(0, 128, 50_000.0)).is_some());
        let (launches, _) = j.issue_ready();
        assert_eq!(launches.len(), 2, "successor issues while head in flight");
        assert_eq!(j.inflight_launches(), 2);
        // seq order at issue is preserved
        assert!(launches[0].issue_us <= launches[1].issue_us);
        for l in launches {
            let run = j.run_issued(l.ticket);
            j.finish_launch(l.ticket, l.issue_us + run.duration_us, run);
        }
        assert!(j.window.is_empty());
    }
}
