//! SLO-aware OoO scheduling (§5.2): EDF base order, slack-driven
//! staggering, coalescing window, straggler eviction.
//!
//! The core tension the paper identifies: launching a ready kernel *now*
//! wastes the chance to coalesce with kernels arriving moments later, but
//! waiting burns SLO slack. The scheduler resolves it with a bounded
//! *coalescing window*: a pack is held while (a) every member still has
//! slack beyond the safety margin, and (b) the oldest member has waited
//! less than the window — "purposefully delays/staggers ill-fitting kernels
//! for better coalescing at a (slightly) later time" (§5).
//!
//! # The SLO-class contract
//!
//! Every op carries an [`SloClass`]; the scheduler is the layer that turns
//! the class into priority. The contract, shared with the frontend gate
//! (`serve/frontend.rs`) and the JIT eviction path:
//!
//! - **Weight semantics** ([`Policy::class_weights`], indexed by
//!   [`SloClass::index`]): ordering uses the *class-weighted virtual
//!   deadline* — for time-to-deadline `ttd = deadline − now` and weight
//!   `w`, the key is `now + ttd/w` while `ttd ≥ 0` and `now + ttd·w` once
//!   overdue. Weight 1 (the Standard default) makes the key *exactly* the
//!   raw deadline, so single-class workloads reproduce pure EDF
//!   bit-for-bit. A weight > 1 (Critical) shrinks apparent slack — the op
//!   sorts as if its deadline were closer and, once late, as *more*
//!   overdue; a weight < 1 (BestEffort) stretches it. Weighted fair
//!   sharing of pack capacity falls out: a saturating best-effort tenant's
//!   ops sort behind any critical op whose scaled slack is tighter, and
//!   classes never share a pack (the coalescer buckets by class).
//! - **Yield rule**: a *full* best-effort pack — normally launched
//!   immediately — defers while any ready higher-class op's slack is
//!   within `safety_margin_us` of the time the pack would occupy the
//!   device (`slack < pack_est + margin`). Best-effort still makes
//!   progress whenever critical load leaves that much slack (bounded
//!   starvation, pinned by test).
//! - **Eviction order**: best-effort stragglers are evicted on a *tighter*
//!   threshold — `eviction_factor × be_eviction_scale` (default ½) — so
//!   when a device degrades, best-effort work is killed first and critical
//!   work keeps the standard grace. The time charged to an evicted launch
//!   always equals its class's trigger threshold
//!   ([`Scheduler::eviction_charge_us_class`]).
//! - **Rate-limit accounting** lives in the frontend gate (per-tenant
//!   token buckets) — the scheduler never sees shed requests.
//!
//! # The incremental decide contract
//!
//! [`Scheduler::decide`] is incremental: the scheduler keeps a persistent
//! mirror of the window's ready set, bucketed by the coalescer's ONE
//! bucketing rule ([`Coalescer::bucket_key_of`]: `(group, SLO class,
//! shape class)`), and maintained from the window's ready-set delta log
//! ([`crate::compiler::window::ReadyDelta`], drained via
//! [`crate::compiler::window::Window::take_ready_deltas`]) instead of a
//! per-call rescan. [`Scheduler::decide_naive`] is the from-scratch
//! reference implementation; the two are pinned bit-identical by a
//! property-test oracle over randomized admit/issue/requeue/complete
//! interleavings.
//!
//! **What marks a bucket dirty.** Any membership change: an op entering
//! the ready set (admitted ready, unblocked by an issue, promoted after a
//! requeue) or leaving it (issued, demoted behind a requeued dependent
//! op) dirties exactly its own `(group, class, shape)` bucket. A decide
//! re-chunks and re-prices *dirty* buckets only; clean buckets reuse
//! their cached packs verbatim — including each pack's kernel estimate
//! and its `hold_until` launch deadline, both of which are
//! `now`-independent (`hold_until = min(member deadlines) − est − margin,
//! capped at oldest arrival + coalesce window`).
//!
//! **What the caches key on.** Bucket-internal member order is
//! `(deadline, op id)` — for a fixed class the class-weighted virtual
//! deadline is strictly monotone in the raw deadline at every `now`
//! (both the `ttd ≥ 0` and overdue branches scale a monotone function of
//! `ttd`), so weighted-EDF order inside a bucket is time-invariant and
//! cacheable. (Edge: two *distinct* deadlines whose virtual deadlines
//! collide after rounding would tie-break by id in the naive sort but by
//! deadline here; sub-ulp deadline spacing is the only way to hit it.)
//! Only two things are computed fresh per decide, both O(buckets +
//! packs): the cross-bucket pack order (virtual deadline of each pack's
//! cached head, sorted into a reusable scratch array — no per-comparison
//! recomputation, no window lookups) and the best-effort yield check
//! (the minimum non-best-effort head deadline stands in for the naive
//! scan over every ready op — the slack test is monotone in the
//! deadline, so only the minimum can decide it). Cached kernel estimates
//! are additionally invalidated by the estimator *generation counter*
//! (`est_gen`, the tiered estimator's tier-change signal): a bumped
//! generation dirties every bucket, an unchanged generation reuses
//! cached estimates even if the estimator's EWMA drifted — estimate
//! reuse between generation bumps is part of this contract (and makes
//! `Wait` monotonicity strictly stronger than the naive path's).
//!
//! **Resync.** The mirror is keyed to one window identity
//! ([`crate::compiler::window::Window::stamp`]); a stamp mismatch or a
//! delta-log overflow abandons the cache and rebuilds from
//! `window.ready()`. Cloning a scheduler resets the cache (a clone will
//! drain a different window's deltas — or compete for this one's).

use std::collections::{BTreeMap, HashMap};

use crate::compiler::coalescer::{Coalescer, ShapeClass, SuperKernel};
use crate::compiler::ir::{OpId, SloClass, TensorOp};
use crate::compiler::window::{ReadyDelta, Window};
use crate::gpu::kernel::KernelDesc;

/// Scheduling policy knobs.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Max artificial delay for coalescing, µs.
    pub coalesce_window_us: f64,
    /// Launch immediately once a pack reaches this many problems.
    pub target_pack: usize,
    /// Slack reserve: launch when `deadline − now − est` falls below this.
    pub safety_margin_us: f64,
    /// Evict an in-flight op when its runtime exceeds `eviction_factor ×`
    /// its estimate (§5.2 "simply evict degraded workers").
    pub eviction_factor: f64,
    /// Absolute slop added to the eviction threshold, µs — keeps tiny
    /// kernels (estimate ≈ 0) from being evicted on scheduling noise. The
    /// eviction charge in the JIT uses the same slop, so the time billed
    /// to an evicted straggler equals the trigger threshold.
    pub eviction_slop_us: f64,
    /// EWMA smoothing factor for the Measured estimate tier
    /// (`crate::estimate`), in (0, 1]. Higher = more reactive to the
    /// latest launch duration, lower = smoother under co-tenancy noise.
    /// Was a hard-coded `Ewma::new(0.3)` scattered across the executors;
    /// hoisted here so estimate reactivity is tunable and documented in
    /// one place.
    pub ewma_alpha: f64,
    /// Fair-share weight per [`SloClass`] (indexed by
    /// [`SloClass::index`]). The scheduler orders by the class-weighted
    /// virtual deadline (see the module doc); weight 1.0 reproduces pure
    /// EDF for that class. Defaults: Critical 4×, Standard 1×,
    /// BestEffort ¼×.
    pub class_weights: [f64; 3],
    /// Scale applied to `eviction_factor` for best-effort launches —
    /// best-effort stragglers are killed on a tighter threshold so a
    /// degraded device sheds batch work before critical work. 1.0
    /// disables the preference.
    pub be_eviction_scale: f64,
    /// Base Tuned-tier refinement cadence for the tiered estimator: after
    /// this many observations the hottest measured variants are promoted
    /// back into the Tuned tier. 0 disables refinement. The *effective*
    /// cadence adapts around this base (see
    /// [`Policy::refine_err_threshold_us`]).
    pub refine_period: u64,
    /// How many of the hottest variants each refinement pass promotes.
    pub refine_top: usize,
    /// Estimate-error p99 threshold (µs) steering the adaptive cadence:
    /// while the observed `err_p99` exceeds this the estimator re-tunes
    /// on a quarter of `refine_period`; once the Measured tier dominates
    /// the answer stream (and error is below threshold) it backs off to
    /// 4× the base period.
    pub refine_err_threshold_us: f64,
    /// Run the machine plan verifier ([`crate::analysis::plan`]) over
    /// every coalesced plan at issue time. Default on under
    /// `debug_assertions` (tests fail-stop on a hazardous superkernel),
    /// off in release hot paths; `vliwd bench --verify` and
    /// `--verify-plans` force it on to measure the overhead.
    pub verify_plans: bool,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            coalesce_window_us: 2_000.0,
            target_pack: 4,
            safety_margin_us: 500.0,
            eviction_factor: 3.0,
            eviction_slop_us: 50.0,
            ewma_alpha: 0.3,
            class_weights: [4.0, 1.0, 0.25],
            be_eviction_scale: 0.5,
            refine_period: 64,
            refine_top: 8,
            refine_err_threshold_us: 500.0,
            verify_plans: cfg!(debug_assertions),
        }
    }
}

impl Policy {
    /// Fair-share weight of a class, clamped positive.
    pub fn weight_of(&self, class: SloClass) -> f64 {
        self.class_weights[class.index()].max(1e-6)
    }

    /// Class-weighted virtual deadline of an op at `now` — the scheduler's
    /// ordering key. Equals the raw deadline when the class weight is 1.
    pub fn virtual_deadline_us(&self, op: &TensorOp, now: f64) -> f64 {
        self.virtual_deadline_key(op.deadline_us, op.class, now)
    }

    /// The virtual-deadline key from its raw parts — the incremental
    /// decide path computes it from cached `(head deadline, class)`
    /// scalars without touching the window. Bit-identical to
    /// [`Policy::virtual_deadline_us`].
    pub fn virtual_deadline_key(&self, deadline_us: f64, class: SloClass, now: f64) -> f64 {
        let w = self.weight_of(class);
        let ttd = deadline_us - now;
        if ttd >= 0.0 {
            now + ttd / w
        } else {
            now + ttd * w
        }
    }

    /// Eviction factor for a class (best-effort runs on the tighter,
    /// scaled threshold).
    pub fn eviction_factor_of(&self, class: SloClass) -> f64 {
        match class {
            SloClass::BestEffort => self.eviction_factor * self.be_eviction_scale,
            _ => self.eviction_factor,
        }
    }
}

/// A scheduling decision for the current instant.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Launch this superkernel now.
    Launch(SuperKernel),
    /// Nothing should launch before this time (stagger for coalescing).
    Wait {
        /// Re-evaluate at this time, µs.
        until_us: f64,
    },
    /// Window empty.
    Idle,
}

/// A bucket's identity: the coalescer's one bucketing rule
/// ([`Coalescer::bucket_key_of`]).
type BucketKey = (u64, SloClass, ShapeClass);

/// A cached pack of one bucket chunk, with everything the decision loop
/// needs as `now`-independent scalars (see the module doc's incremental
/// contract): the built superkernel, its kernel estimate at the cache's
/// estimator generation, its hold deadline, and its head's raw ordering
/// key parts.
#[derive(Debug, Clone)]
struct CachedPack {
    sk: SuperKernel,
    est_us: f64,
    hold_until_us: f64,
    head_deadline_us: f64,
    head_id: OpId,
}

/// One bucket of the persistent ready-set mirror.
#[derive(Debug, Clone, Default)]
struct Bucket {
    /// `(deadline_us, id)` ascending — the weighted-EDF order of a fixed
    /// class at ANY `now` (virtual deadline is strictly monotone in the
    /// raw deadline), so membership order is time-invariant.
    members: Vec<(f64, OpId)>,
    /// Cached chunking of `members`, valid while `dirty` is false.
    packs: Vec<CachedPack>,
    dirty: bool,
}

/// Persistent incremental-decide state (see the module doc).
#[derive(Debug, Default)]
struct DecideCache {
    buckets: BTreeMap<BucketKey, Bucket>,
    /// id → (bucket, deadline): locates a leaving op without the window
    /// (it may already have completed by drain time).
    op_index: HashMap<OpId, (BucketKey, f64)>,
    /// The window identity this mirror tracks; a mismatch forces resync.
    synced_stamp: Option<u64>,
    /// Estimator generation the cached `est_us` values were priced at.
    est_gen: u64,
    /// Scratch: drained window deltas (allocation reused across decides).
    delta_scratch: Vec<ReadyDelta>,
    /// Scratch: cross-bucket pack order `(vd, head id, bucket, pack idx)`
    /// — keys computed ONCE per pack per decide, then sorted; no
    /// per-comparison recomputation or window lookups.
    order_scratch: Vec<(f64, OpId, BucketKey, u32)>,
    /// Cumulative clean-bucket reuses across decides (observability).
    buckets_reused: u64,
    /// Cumulative dirty-bucket repacks across decides (observability).
    buckets_repacked: u64,
}

impl DecideCache {
    fn insert(&mut self, key: BucketKey, deadline_us: f64, id: OpId) {
        let b = self.buckets.entry(key).or_default();
        let pos = b
            .members
            .partition_point(|&(d, i)| d < deadline_us || (d == deadline_us && i < id));
        b.members.insert(pos, (deadline_us, id));
        b.dirty = true;
        let prev = self.op_index.insert(id, (key, deadline_us));
        debug_assert!(prev.is_none(), "op {id:?} entered the mirror twice");
    }

    fn remove(&mut self, id: OpId) {
        let Some((key, deadline_us)) = self.op_index.remove(&id) else {
            // an Enter skipped because the op had already left the window
            // (completed between decides) pairs with this no-op Leave
            return;
        };
        let b = self.buckets.get_mut(&key).expect("indexed bucket exists");
        let pos = b
            .members
            .partition_point(|&(d, i)| d < deadline_us || (d == deadline_us && i < id));
        debug_assert_eq!(b.members.get(pos), Some(&(deadline_us, id)));
        b.members.remove(pos);
        b.dirty = true;
    }
}

/// The OoO scheduler.
#[derive(Debug, Default)]
pub struct Scheduler {
    /// Policy knobs.
    pub policy: Policy,
    /// Packing rules.
    pub coalescer: Coalescer,
    /// Persistent incremental-decide state. Never consulted by
    /// [`Scheduler::decide_naive`].
    cache: DecideCache,
}

impl Clone for Scheduler {
    /// Clones policy and packing rules but resets the decide cache: the
    /// mirror tracks ONE window's delta stream, and a clone would either
    /// drain a different window or compete with the original for this
    /// one's deltas — cold-starting the clone is the only safe option.
    fn clone(&self) -> Self {
        Scheduler {
            policy: self.policy.clone(),
            coalescer: self.coalescer.clone(),
            cache: DecideCache::default(),
        }
    }
}

impl Scheduler {
    /// New scheduler.
    pub fn new(policy: Policy, coalescer: Coalescer) -> Self {
        Scheduler {
            policy,
            coalescer,
            cache: DecideCache::default(),
        }
    }

    /// Clean-bucket reuses across this scheduler's lifetime (each decide
    /// counts every bucket it kept without repacking).
    pub fn buckets_reused(&self) -> u64 {
        self.cache.buckets_reused
    }

    /// Dirty-bucket repacks across this scheduler's lifetime.
    pub fn buckets_repacked(&self) -> u64 {
        self.cache.buckets_repacked
    }

    /// Decide what to do at time `now` — the incremental path (see the
    /// module doc's contract): drains the window's ready-set deltas,
    /// repacks and re-prices only the dirty `(group, class, shape)`
    /// buckets, and reuses every clean bucket's cached packs, hold
    /// deadlines, and kernel estimates. `est_gen` is the estimator's
    /// generation counter ([`crate::estimate::TieredEstimator::generation`]
    /// for the serving stack; any constant for generation-free
    /// estimators): a change invalidates every cached estimate.
    ///
    /// Decisions are bit-identical to [`Scheduler::decide_naive`] at the
    /// same `(window state, now, estimates)` — pinned by the naive-oracle
    /// property test. `est_exec` must be a pure function of its inputs
    /// between generation bumps; within one generation the cached value
    /// is reused without re-asking.
    ///
    /// `Wait { until_us }` is monotone for a fixed window — and with the
    /// cache it is monotone even across estimator drift within one
    /// generation, since the promised wake-up was computed from the very
    /// estimate the cache replays.
    pub fn decide<F>(
        &mut self,
        window: &mut Window,
        now: f64,
        est_gen: u64,
        est_exec: F,
    ) -> Decision
    where
        F: Fn(&KernelDesc, &[&TensorOp]) -> f64,
    {
        let Scheduler {
            policy,
            coalescer,
            cache,
        } = self;
        // 1. sync the mirror: drain deltas, or resync from scratch on a
        // window-identity change / delta-log overflow
        let overflow = window.take_ready_deltas(&mut cache.delta_scratch);
        let win: &Window = window;
        if overflow || cache.synced_stamp != Some(win.stamp()) {
            cache.buckets.clear();
            cache.op_index.clear();
            for op in win.ready() {
                cache.insert(coalescer.bucket_key_of(op), op.deadline_us, op.id);
            }
            cache.synced_stamp = Some(win.stamp());
        } else {
            for i in 0..cache.delta_scratch.len() {
                let delta = cache.delta_scratch[i];
                match delta {
                    ReadyDelta::Enter(id) => {
                        // an op that entered and left the window again
                        // before this drain resolves to nothing here; its
                        // Leave below is a no-op too
                        if let Some(op) = win.get(id) {
                            cache.insert(coalescer.bucket_key_of(op), op.deadline_us, op.id);
                        }
                    }
                    ReadyDelta::Leave(id) => cache.remove(id),
                }
            }
        }
        // the mirror IS the ready set — the invariant every cached
        // decision rests on (stale-cache hazard guard, debug builds)
        debug_assert_eq!(
            cache.op_index.len(),
            win.ready_count(),
            "bucket mirror diverged from the window's ready set"
        );
        // 2. estimator generation bump: every cached estimate is stale
        if est_gen != cache.est_gen {
            cache.est_gen = est_gen;
            for b in cache.buckets.values_mut() {
                b.dirty = true;
            }
        }
        cache.buckets.retain(|_, b| !b.members.is_empty());
        if cache.buckets.is_empty() {
            return Decision::Idle;
        }
        // 3. repack + re-price dirty buckets only
        let DecideCache {
            buckets,
            order_scratch,
            buckets_reused,
            buckets_repacked,
            ..
        } = cache;
        let mut member_refs: Vec<&TensorOp> = Vec::new();
        for (key, bucket) in buckets.iter_mut() {
            if !bucket.dirty {
                *buckets_reused += 1;
                continue;
            }
            *buckets_repacked += 1;
            bucket.packs.clear();
            let cap = coalescer.cap_of(key.0);
            for chunk in bucket.members.chunks(cap) {
                member_refs.clear();
                member_refs.extend(
                    chunk
                        .iter()
                        .map(|&(_, id)| win.get(id).expect("mirrored op in window")),
                );
                // useful FLOPs summed in pack order: bit-identical to the
                // naive path's construction
                let useful: f64 = member_refs.iter().map(|o| o.kernel.flops()).sum();
                let kernel = key.2.kernel(chunk.len() as u32);
                let est = est_exec(&kernel, &member_refs);
                let min_deadline = member_refs
                    .iter()
                    .map(|op| op.deadline_us)
                    .fold(f64::INFINITY, f64::min);
                let oldest_arrival = member_refs
                    .iter()
                    .map(|op| op.arrival_us)
                    .fold(f64::INFINITY, f64::min);
                let critical_us = min_deadline - est - policy.safety_margin_us;
                let window_closes = oldest_arrival + policy.coalesce_window_us;
                bucket.packs.push(CachedPack {
                    sk: SuperKernel {
                        class: key.2,
                        ops: chunk.iter().map(|&(_, id)| id).collect(),
                        useful_flops: useful,
                        kernel: kernel.clone(),
                    },
                    est_us: est,
                    hold_until_us: critical_us.min(window_closes),
                    head_deadline_us: chunk[0].0,
                    head_id: chunk[0].1,
                });
            }
            bucket.dirty = false;
        }
        // 4. best-effort yield pivot: the earliest non-best-effort head
        // deadline — `slack(now, est) < margin` is monotone in the
        // deadline, so the minimum alone decides the naive any-scan
        let mut d_min_nonbe = f64::INFINITY;
        for (key, bucket) in buckets.iter() {
            if key.1 < SloClass::BestEffort {
                if let Some(&(d, _)) = bucket.members.first() {
                    d_min_nonbe = d_min_nonbe.min(d);
                }
            }
        }
        // 5. cross-bucket EDF: virtual deadline of each pack's cached
        // head, computed once into the scratch order array
        order_scratch.clear();
        for (key, bucket) in buckets.iter() {
            for (pi, p) in bucket.packs.iter().enumerate() {
                let vd = policy.virtual_deadline_key(p.head_deadline_us, key.1, now);
                order_scratch.push((vd, p.head_id, *key, pi as u32));
            }
        }
        order_scratch
            .sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        // 6. the decision loop — cached scalars only, no allocation
        let mut earliest_hold = f64::INFINITY;
        for &(_, _, key, pi) in order_scratch.iter() {
            let pack = &buckets[&key].packs[pi as usize];
            let problems = pack.sk.problems();
            let full = problems >= policy.target_pack
                || problems >= coalescer.max_problems
                || problems >= coalescer.cap_of(key.0);
            if full {
                let yields = key.1 == SloClass::BestEffort
                    && d_min_nonbe - now - pack.est_us < policy.safety_margin_us;
                if !yields {
                    return Decision::Launch(pack.sk.clone());
                }
                continue;
            }
            if now + 1e-9 >= pack.hold_until_us {
                return Decision::Launch(pack.sk.clone());
            }
            earliest_hold = earliest_hold.min(pack.hold_until_us);
        }
        Decision::Wait {
            until_us: earliest_hold,
        }
    }

    /// Decide what to do at time `now`, from scratch — the reference
    /// implementation the incremental [`Scheduler::decide`] is pinned
    /// bit-identical against (property-test oracle), and the baseline
    /// `vliwd bench --sched` measures the cache against. `est_exec`
    /// estimates a batched kernel's execution time (µs) given the pack's
    /// member ops — supplied by the executor's cost model so the
    /// scheduler stays backend-agnostic (the serving executor uses the
    /// members' group and count to estimate the padded compiled variant
    /// that will actually run).
    ///
    /// `Wait { until_us }` is monotone for a fixed window: a `decide` at
    /// (or after) `until_us` launches, it never returns a later wait.
    ///
    /// The ready set may contain several ops of ONE stream (the window's
    /// independent-op ready prefix), so a single hot tenant can fill a
    /// pack — and hit the target/cap launch triggers — by itself. The
    /// cap/hold logic is per-pack, never per-stream: a pack at its group
    /// cap launches immediately regardless of how many streams filled it.
    pub fn decide_naive<F>(&self, window: &Window, now: f64, est_exec: F) -> Decision
    where
        F: Fn(&KernelDesc, &[&TensorOp]) -> f64,
    {
        let mut ready = window.ready();
        if ready.is_empty() {
            return Decision::Idle;
        }
        // EDF base order on the class-weighted virtual deadline (the OoO
        // reordering step); with all weights 1 this is the raw deadline.
        // Ties broken by op id so scheduling is fully deterministic (the
        // window hands us ops in hash-map order)
        ready.sort_by(|a, b| {
            let va = self.policy.virtual_deadline_us(a, now);
            let vb = self.policy.virtual_deadline_us(b, now);
            va.partial_cmp(&vb).unwrap().then(a.id.cmp(&b.id))
        });
        let mut packs = self.coalescer.pack(&ready);
        // EDF across packs: order by each pack's most urgent member (= its
        // first member — buckets preserve the weighted-EDF input order),
        // ties by first member id for determinism. The highest-priority
        // *launchable* pack launches; a staggering urgent pack never holds
        // a full pack for another group hostage.
        packs.sort_by(|a, b| {
            let va = self
                .policy
                .virtual_deadline_us(window.get(a.ops[0]).expect("pack member"), now);
            let vb = self
                .policy
                .virtual_deadline_us(window.get(b.ops[0]).expect("pack member"), now);
            va.partial_cmp(&vb).unwrap().then(a.ops[0].cmp(&b.ops[0]))
        });
        let mut earliest_hold = f64::INFINITY;
        for pack in packs {
            // full pack: no reason to wait. "Full" includes the pack's
            // group cap (a model's largest compiled batch variant) — a
            // pack at its cap can never grow, so holding it is pure
            // added latency.
            let head = window.get(pack.ops[0]).expect("pack member");
            let (group, pack_class) = (head.group, head.class);
            let full = pack.problems() >= self.policy.target_pack
                || pack.problems() >= self.coalescer.max_problems
                || pack.problems() >= self.coalescer.cap_of(group);
            if full {
                // Yield rule (class contract, module doc): a full
                // best-effort pack defers while occupying the device with
                // it would eat into a ready higher-class op's safety
                // margin. The higher-class op's own pack either launches
                // this decide or contributes the wake-up time, so the
                // yielding pack re-evaluates once that slack clears.
                let yields = pack_class == SloClass::BestEffort && {
                    let members: Vec<&TensorOp> = pack
                        .ops
                        .iter()
                        .map(|id| window.get(*id).expect("pack member in window"))
                        .collect();
                    let est = est_exec(&pack.kernel, &members);
                    ready.iter().any(|op| {
                        op.class < SloClass::BestEffort
                            && op.slack_us(now, est) < self.policy.safety_margin_us
                    })
                };
                if !yields {
                    return Decision::Launch(pack);
                }
                continue;
            }
            let members: Vec<&TensorOp> = pack
                .ops
                .iter()
                .map(|id| window.get(*id).expect("pack member in window"))
                .collect();
            let est = est_exec(&pack.kernel, &members);
            // latest safe launch time for the pack (tightest member)
            let critical_us = members
                .iter()
                .map(|op| op.deadline_us)
                .fold(f64::INFINITY, f64::min)
                - est
                - self.policy.safety_margin_us;
            // stagger budget: oldest member may wait at most coalesce_window
            let oldest_arrival = members
                .iter()
                .map(|op| op.arrival_us)
                .fold(f64::INFINITY, f64::min);
            let window_closes = oldest_arrival + self.policy.coalesce_window_us;

            let hold_until = critical_us.min(window_closes);
            // launch at (or within float jitter of) the promised wake-up
            // time: a decide at a previously returned `until_us` must never
            // wait again
            if now + 1e-9 >= hold_until {
                return Decision::Launch(pack);
            }
            earliest_hold = earliest_hold.min(hold_until);
        }
        Decision::Wait {
            until_us: earliest_hold,
        }
    }

    /// Straggler test (§5.2): should an op issued at `issued_us` with
    /// estimate `est_us` be evicted at `now`? Standard-class threshold;
    /// class-aware callers use [`Scheduler::should_evict_class`].
    pub fn should_evict(&self, issued_us: f64, est_us: f64, now: f64) -> bool {
        self.should_evict_class(SloClass::Standard, issued_us, est_us, now)
    }

    /// Class-aware straggler test: best-effort launches trip on the
    /// tighter scaled threshold (eviction-order leg of the class
    /// contract), critical and standard keep the full grace.
    pub fn should_evict_class(
        &self,
        class: SloClass,
        issued_us: f64,
        est_us: f64,
        now: f64,
    ) -> bool {
        now - issued_us
            > self.policy.eviction_factor_of(class) * est_us + self.policy.eviction_slop_us
    }

    /// The straggler time charged to an evicted launch: it runs up to the
    /// eviction trigger, then is killed. Kept identical to the
    /// [`Scheduler::should_evict`] threshold so simulated accounting and
    /// the trigger can never drift apart. Standard-class value; see
    /// [`Scheduler::eviction_charge_us_class`].
    pub fn eviction_charge_us(&self, est_us: f64) -> f64 {
        self.eviction_charge_us_class(SloClass::Standard, est_us)
    }

    /// Class-aware eviction charge — equals the
    /// [`Scheduler::should_evict_class`] trigger for the same class.
    pub fn eviction_charge_us_class(&self, class: SloClass, est_us: f64) -> f64 {
        self.policy.eviction_factor_of(class) * est_us + self.policy.eviction_slop_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::{DispatchRequest, StreamId};
    use crate::gpu::cost::CostModel;

    fn est(cm: &CostModel) -> impl Fn(&KernelDesc, &[&TensorOp]) -> f64 + '_ {
        // priced through the estimate subsystem's Prior tier, like every
        // real consumer of the scheduler
        move |k, _ops| {
            crate::estimate::prior::analytic_us(
                cm,
                &crate::gpu::kernel::LaunchConfig::greedy(),
                k,
            )
        }
    }

    fn sched() -> Scheduler {
        Scheduler::new(Policy::default(), Coalescer::default())
    }

    fn submit(w: &mut Window, stream: u32, slo_us: f64, now: f64) {
        w.submit(
            DispatchRequest::new(
                StreamId(stream),
                KernelDesc::gemm(128, 512, 64),
                slo_us,
            ),
            now,
        )
        .unwrap();
    }

    #[test]
    fn idle_on_empty_window() {
        let mut w = Window::new(8);
        let cm = CostModel::v100();
        assert!(matches!(sched().decide(&mut w, 0.0, 0, est(&cm)), Decision::Idle));
    }

    #[test]
    fn small_pack_with_slack_staggers() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 50_000.0, 0.0); // huge slack
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Wait { until_us } => {
                assert!(until_us > 0.0 && until_us <= 2_000.0, "until={until_us}");
            }
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn critical_deadline_launches_immediately() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 600.0, 0.0); // slack ≈ safety margin
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 1),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn full_pack_launches_without_waiting() {
        let mut w = Window::new(16);
        for s in 0..4 {
            submit(&mut w, s, 50_000.0, 0.0);
        }
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 4),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn wait_expires_at_window_close() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 100_000.0, 0.0);
        let cm = CostModel::v100();
        let mut s = sched();
        // before window close: wait
        let until = match s.decide(&mut w, 100.0, 0, est(&cm)) {
            Decision::Wait { until_us } => until_us,
            other => panic!("expected Wait, got {other:?}"),
        };
        // at/after the wait point: launch
        match s.decide(&mut w, until, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 1),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn wait_is_monotone_even_when_estimates_drift() {
        // the promised wake-up must be honored even if the estimator
        // returns a smaller value at the second decide (learned estimates
        // shrink as real measurements come in): a decide at `until_us`
        // launches, it never pushes the wait later
        let mut w = Window::new(8);
        submit(&mut w, 0, 100_000.0, 0.0);
        let cm = CostModel::v100();
        let mut s = sched();
        let until = match s.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Wait { until_us } => until_us,
            other => panic!("expected Wait, got {other:?}"),
        };
        // estimator drops to one tenth of the cost-model time
        let drifted = |k: &KernelDesc, _ops: &[&TensorOp]| {
            crate::estimate::prior::analytic_us(
                &cm,
                &crate::gpu::kernel::LaunchConfig::greedy(),
                k,
            ) / 10.0
        };
        match s.decide(&mut w, until, 0, drifted) {
            Decision::Launch(_) => {}
            Decision::Wait { until_us } => {
                panic!("wait at {until} re-postponed to {until_us}")
            }
            Decision::Idle => unreachable!(),
        }
    }

    #[test]
    fn edf_orders_pack_priority() {
        let mut w = Window::new(8);
        // stream 0: relaxed; stream 1: tight and incompatible shape
        w.submit(
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(128, 512, 64), 90_000.0),
            0.0,
        )
        .unwrap();
        w.submit(
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(2048, 2048, 2048), 900.0),
            0.0,
        )
        .unwrap();
        let cm = CostModel::v100();
        // the urgent (big) op's pack must be chosen, not the relaxed one's
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => {
                assert_eq!(p.kernel.m, 2048);
            }
            Decision::Wait { .. } => panic!("urgent op must launch"),
            Decision::Idle => unreachable!(),
        }
    }

    #[test]
    fn pack_at_group_cap_launches_without_waiting() {
        // a pack that has reached its group cap (a model's largest
        // compiled batch variant) can never grow — it must launch even
        // though it is below target_pack and the global max_problems
        let mut w = Window::new(8);
        for s in 0..2 {
            w.submit(
                DispatchRequest::new(
                    StreamId(s),
                    KernelDesc::gemm(128, 512, 64),
                    50_000.0, // huge slack: only the cap forces the launch
                )
                .with_group(3),
                0.0,
            )
            .unwrap();
        }
        let mut s = Scheduler::new(
            Policy::default(), // target_pack 4
            Coalescer::new(8, 0.75).with_group_cap(3, 2),
        );
        let cm = CostModel::v100();
        match s.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 2),
            other => panic!("capped pack must launch, got {other:?}"),
        }
    }

    #[test]
    fn single_stream_burst_fills_a_pack_by_itself() {
        // 8 independent ops of ONE stream: the ready prefix exposes all of
        // them and the pack reaches max_problems — launch without waiting,
        // exactly like 8 distinct streams would
        let mut w = Window::new(16);
        for _ in 0..8 {
            w.submit(
                DispatchRequest::new(
                    StreamId(0),
                    KernelDesc::gemm(128, 512, 64),
                    50_000.0,
                )
                .with_independent(true),
                0.0,
            )
            .unwrap();
        }
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 8),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn single_stream_pack_at_group_cap_launches_immediately() {
        // one stream fills its model's cap alone: the cap trigger must not
        // assume one-op-per-stream
        let mut w = Window::new(8);
        for _ in 0..2 {
            w.submit(
                DispatchRequest::new(
                    StreamId(0),
                    KernelDesc::gemm(128, 512, 64),
                    50_000.0,
                )
                .with_group(3)
                .with_independent(true),
                0.0,
            )
            .unwrap();
        }
        let mut s = Scheduler::new(
            Policy::default(), // target_pack 4
            Coalescer::new(8, 0.75).with_group_cap(3, 2),
        );
        let cm = CostModel::v100();
        match s.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 2),
            other => panic!("capped single-stream pack must launch, got {other:?}"),
        }
    }

    #[test]
    fn dependent_stream_stays_one_ready_op() {
        // without the independence flag only the head is ready — a burst
        // from a stateful stream cannot fill a pack
        let mut w = Window::new(16);
        for _ in 0..8 {
            submit(&mut w, 0, 600.0, 0.0); // tight: forces launch now
        }
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 1),
            other => panic!("expected singleton Launch, got {other:?}"),
        }
    }

    #[test]
    fn staggering_urgent_pack_does_not_hold_full_pack_hostage() {
        let mut w = Window::new(16);
        // stream 0: the urgent op (earliest deadline) with plenty of slack
        // — its singleton pack staggers for coalescing
        submit(&mut w, 0, 50_000.0, 0.0);
        // streams 1..=4: a FULL pack of an incompatible shape, later
        // deadlines — must not idle behind the staggering urgent pack
        for s in 1..=4 {
            w.submit(
                DispatchRequest::new(
                    StreamId(s),
                    KernelDesc::gemm(2048, 2048, 2048),
                    60_000.0,
                ),
                0.0,
            )
            .unwrap();
        }
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => {
                assert_eq!(p.problems(), 4, "the full pack launches");
                assert_eq!(p.kernel.m, 2048);
            }
            other => panic!("expected Launch of the full pack, got {other:?}"),
        }
    }

    #[test]
    fn standard_weight_reproduces_raw_deadline() {
        // the virtual deadline of a Standard-class op IS the raw deadline
        // (weight 1), so pre-class EDF behaviour is reproduced exactly
        let p = Policy::default();
        let mut w = Window::new(8);
        submit(&mut w, 0, 5_000.0, 0.0);
        let op = w.ready()[0];
        assert_eq!(p.virtual_deadline_us(op, 0.0), op.deadline_us);
        assert_eq!(p.virtual_deadline_us(op, 7_000.0), op.deadline_us);
    }

    #[test]
    fn class_weights_reorder_packs() {
        use crate::compiler::ir::SloClass;
        // best-effort op with a NOMINALLY earlier deadline vs a critical
        // op: the 4×/¼× weights invert the order (weighted virtual
        // deadline: critical 0 + 40_000/4 = 10_000 < be 0 + 30_000/0.25 =
        // 120_000), so the critical pack launches first
        let mut w = Window::new(8);
        w.submit(
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(128, 512, 64), 30_000.0)
                .with_class(SloClass::BestEffort),
            0.0,
        )
        .unwrap();
        w.submit(
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(128, 512, 64), 40_000.0)
                .with_class(SloClass::Critical),
            0.0,
        )
        .unwrap();
        let mut s = Scheduler::new(
            Policy {
                coalesce_window_us: 0.0, // launch immediately: order is the test
                ..Policy::default()
            },
            Coalescer::default(),
        );
        let cm = CostModel::v100();
        match s.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => {
                let head = w.get(p.ops[0]).unwrap();
                assert_eq!(head.class, SloClass::Critical, "critical pack first");
            }
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn full_best_effort_pack_yields_to_tight_critical_slack() {
        use crate::compiler::ir::SloClass;
        // a FULL best-effort pack normally launches immediately (the
        // hostage scenario); with a ready critical op whose slack is
        // inside (pack est + margin) it must yield instead — the decision
        // is the critical launch or the critical pack's stagger, never
        // the best-effort launch
        let cm = CostModel::v100();
        let pack_est = crate::estimate::prior::analytic_us(
            &cm,
            &crate::gpu::kernel::LaunchConfig::greedy(),
            &KernelDesc::batched(4, 128, 512, 64),
        );
        let mut w = Window::new(16);
        for s in 0..4 {
            w.submit(
                DispatchRequest::new(
                    StreamId(s),
                    KernelDesc::gemm(128, 512, 64),
                    50_000.0,
                )
                .with_class(SloClass::BestEffort),
                0.0,
            )
            .unwrap();
        }
        // critical op (tiny kernel, different shape class): slack after a
        // BE pack launch would be 300µs < the 500µs safety margin
        w.submit(
            DispatchRequest::new(StreamId(9), KernelDesc::gemm(1, 4, 4), pack_est + 300.0)
                .with_class(SloClass::Critical),
            0.0,
        )
        .unwrap();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => {
                let head = w.get(p.ops[0]).unwrap();
                assert_eq!(head.class, SloClass::Critical, "BE pack yielded");
            }
            Decision::Wait { until_us } => {
                // the critical pack staggers briefly; the yielded BE pack
                // must not sneak in at the wake-up while slack stays tight
                assert!(until_us.is_finite());
            }
            Decision::Idle => unreachable!(),
        }
    }

    #[test]
    fn full_best_effort_pack_launches_when_critical_slack_is_generous() {
        use crate::compiler::ir::SloClass;
        // bounded starvation: with the critical op's slack comfortably
        // beyond (pack est + margin) the full best-effort pack proceeds
        let mut w = Window::new(16);
        for s in 0..4 {
            w.submit(
                DispatchRequest::new(
                    StreamId(s),
                    KernelDesc::gemm(128, 512, 64),
                    50_000.0,
                )
                .with_class(SloClass::BestEffort),
                0.0,
            )
            .unwrap();
        }
        w.submit(
            DispatchRequest::new(StreamId(9), KernelDesc::gemm(128, 512, 64), 80_000.0)
                .with_class(SloClass::Critical),
            0.0,
        )
        .unwrap();
        let cm = CostModel::v100();
        match sched().decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => {
                let head = w.get(p.ops[0]).unwrap();
                assert_eq!(head.class, SloClass::BestEffort);
                assert_eq!(p.problems(), 4, "the full BE pack launches");
            }
            other => panic!("expected BE Launch, got {other:?}"),
        }
    }

    /// Bit-identical Decision comparison for the oracle tests: `Wait`
    /// times compare by bits, launches by member ids, class, kernel, and
    /// the bit pattern of the chunk-order FLOP sum.
    fn assert_decisions_identical(expect: &Decision, got: &Decision, ctx: &str) {
        match (expect, got) {
            (Decision::Idle, Decision::Idle) => {}
            (Decision::Wait { until_us: a }, Decision::Wait { until_us: b }) => {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: wait {a} vs {b}");
            }
            (Decision::Launch(p), Decision::Launch(q)) => {
                assert_eq!(p.ops, q.ops, "{ctx}: pack members");
                assert_eq!(p.class, q.class, "{ctx}: shape class");
                assert_eq!(p.kernel, q.kernel, "{ctx}: batched kernel");
                assert_eq!(
                    p.useful_flops.to_bits(),
                    q.useful_flops.to_bits(),
                    "{ctx}: useful flops"
                );
            }
            _ => panic!("{ctx}: decisions diverge: {expect:?} vs {got:?}"),
        }
    }

    #[test]
    fn prop_incremental_decide_matches_naive_oracle() {
        use crate::util::rng::Rng;
        // randomized submit/issue/requeue/complete/time-advance
        // interleavings: after every mutation the incremental decision
        // must be bit-identical to the from-scratch naive one, and every
        // incremental Launch must pass the machine plan verifier
        let cm = CostModel::v100();
        let shapes = [(32u32, 256u32, 256u32), (128, 512, 64), (1, 1536, 4096)];
        let mut total_reused = 0u64;
        for seed in 0..20u64 {
            let mut rng = Rng::new(0xDEC1DE ^ seed);
            let mut w = Window::new(64);
            let mut inc = sched();
            let naive = sched();
            let mut now = 0.0f64;
            let mut inflight: Vec<OpId> = Vec::new();
            for step in 0..200 {
                match rng.below(100) {
                    0..=39 => {
                        let (m, k, n) = shapes[rng.below(3) as usize];
                        let class = match rng.below(3) {
                            0 => SloClass::Critical,
                            1 => SloClass::Standard,
                            _ => SloClass::BestEffort,
                        };
                        let req = crate::compiler::ir::DispatchRequest::new(
                            StreamId(rng.below(6) as u32),
                            KernelDesc::gemm(m, k, n),
                            rng.range(500.0, 60_000.0),
                        )
                        .with_class(class)
                        .with_group(rng.below(2))
                        .with_independent(rng.below(2) == 0);
                        let _ = w.submit(req, now);
                    }
                    40..=64 => {
                        if let Decision::Launch(p) = inc.decide(&mut w, now, 0, est(&cm))
                        {
                            let v = crate::analysis::plan::verify_pack(
                                &w,
                                &inc.coalescer,
                                &p,
                                &[],
                            );
                            assert!(v.is_empty(), "seed {seed} step {step}: {v:?}");
                            w.issue(&p.ops);
                            inflight.extend(p.ops.iter().copied());
                        }
                    }
                    65..=79 => {
                        if !inflight.is_empty() {
                            let i = rng.below(inflight.len() as u64) as usize;
                            let id = inflight.swap_remove(i);
                            w.complete(id);
                        }
                    }
                    80..=89 => {
                        if !inflight.is_empty() {
                            let i = rng.below(inflight.len() as u64) as usize;
                            let id = inflight.swap_remove(i);
                            w.requeue(id);
                        }
                    }
                    _ => now += rng.range(0.0, 1_500.0),
                }
                let expect = naive.decide_naive(&w, now, est(&cm));
                let got = inc.decide(&mut w, now, 0, est(&cm));
                assert_decisions_identical(
                    &expect,
                    &got,
                    &format!("seed {seed} step {step}"),
                );
            }
            total_reused += inc.buckets_reused();
        }
        assert!(total_reused > 0, "the cache never reused a clean bucket");
    }

    #[test]
    fn estimator_generation_bump_invalidates_cached_estimates() {
        use std::cell::Cell;
        // contract: within one generation a cached estimate is replayed
        // even if the estimator's answer drifts; a generation bump
        // re-prices every bucket
        let mut w = Window::new(8);
        submit(&mut w, 0, 3_000.0, 0.0); // deadline 3000: critical term binds
        let mut s = sched();
        let scale = Cell::new(1_000.0);
        let est_fn = |_k: &KernelDesc, _ops: &[&TensorOp]| scale.get();
        // gen 0, priced at 1000: hold = 3000 − 1000 − 500(margin) = 1500
        match s.decide(&mut w, 0.0, 0, est_fn) {
            Decision::Wait { until_us } => assert_eq!(until_us, 1_500.0),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert_eq!((s.buckets_repacked(), s.buckets_reused()), (1, 0));
        // estimator drifts WITHOUT a generation bump: cached estimate
        // replayed, bucket not repacked
        scale.set(2_000.0);
        match s.decide(&mut w, 0.0, 0, est_fn) {
            Decision::Wait { until_us } => assert_eq!(until_us, 1_500.0, "cached"),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert_eq!((s.buckets_repacked(), s.buckets_reused()), (1, 1));
        // the bump invalidates: repriced at 2000 → hold = 500
        match s.decide(&mut w, 0.0, 1, est_fn) {
            Decision::Wait { until_us } => assert_eq!(until_us, 500.0, "repriced"),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert_eq!(s.buckets_repacked(), 2);
    }

    #[test]
    fn incremental_cache_order_survives_interleaving_round_trip() {
        // determinism-contract regression over the incremental path: an
        // issue + scrambled-requeue round trip returns the window to the
        // same ready state — the mirror's bucket order, and therefore the
        // decision, must be identical to before, not an artifact of the
        // delta application history (the stale-cache-order hazard)
        let mut w = Window::new(16);
        for s in 0..4 {
            submit(&mut w, s, 50_000.0, 0.0);
        }
        let cm = CostModel::v100();
        let mut s1 = sched();
        let before = match s1.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => p,
            other => panic!("full pack must launch, got {other:?}"),
        };
        w.issue(&before.ops);
        assert!(matches!(s1.decide(&mut w, 0.0, 0, est(&cm)), Decision::Idle));
        for id in before.ops.iter().rev() {
            w.requeue(*id); // reverse order: scrambled delta history
        }
        let after = match s1.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => p,
            other => panic!("restored pack must launch, got {other:?}"),
        };
        assert_eq!(format!("{before:?}"), format!("{after:?}"));
        // and the round-tripped incremental decision still matches naive
        let naive = sched().decide_naive(&w, 0.0, est(&cm));
        assert_decisions_identical(
            &naive,
            &Decision::Launch(after),
            "round trip vs naive",
        );
    }

    #[test]
    fn mutation_stale_cached_pack_is_caught_by_verify_pack() {
        use crate::analysis::plan::{only_rule, rule_ids, verify_pack};
        // seeded stale-bucket hazard: a cached pack replayed after its
        // members issued must be rejected by the machine verifier with
        // the exact ready-prefix rule, not silently double-issued
        let mut w = Window::new(16);
        for s in 0..4 {
            submit(&mut w, s, 50_000.0, 0.0);
        }
        let cm = CostModel::v100();
        let mut s1 = sched();
        let stale = match s1.decide(&mut w, 0.0, 0, est(&cm)) {
            Decision::Launch(p) => p,
            other => panic!("full pack must launch, got {other:?}"),
        };
        w.issue(&stale.ops); // members are now InFlight: the plan is stale
        let v = verify_pack(&w, &s1.coalescer, &stale, &[]);
        assert!(only_rule(&v, "PLAN006"), "stale plan must trip PLAN006: {v:?}");
        assert_eq!(v.len(), stale.ops.len(), "every member flagged");
        // against the live-launch table it is also a double issue
        let v = verify_pack(&w, &s1.coalescer, &stale, &[&stale]);
        let ids = rule_ids(&v);
        assert_eq!(ids, vec!["PLAN006", "PLAN007"], "{v:?}");
    }

    #[test]
    fn eviction_threshold() {
        let s = sched();
        assert!(!s.should_evict(0.0, 100.0, 200.0)); // 2x: fine
        assert!(s.should_evict(0.0, 100.0, 400.0)); // 4x: evict
    }

    #[test]
    fn eviction_slop_is_a_policy_knob_and_matches_charge() {
        let p = Policy {
            eviction_factor: 2.0,
            eviction_slop_us: 10.0,
            ..Policy::default()
        };
        let s = Scheduler::new(p, Coalescer::default());
        // threshold = 2×est + slop = 210
        assert!(!s.should_evict(0.0, 100.0, 210.0));
        assert!(s.should_evict(0.0, 100.0, 210.1));
        // the charged straggler time equals the trigger threshold
        assert_eq!(s.eviction_charge_us(100.0), 210.0);
        // zero-estimate kernels are protected by the slop alone
        assert!(!s.should_evict(0.0, 0.0, 9.0));
        assert!(s.should_evict(0.0, 0.0, 11.0));
    }

    #[test]
    fn best_effort_evicts_on_tighter_threshold_and_charge_matches() {
        use crate::compiler::ir::SloClass;
        let s = sched(); // factor 3, BE scale 0.5, slop 50
        // standard threshold: 3×100 + 50 = 350; BE: 1.5×100 + 50 = 200
        assert!(!s.should_evict_class(SloClass::Standard, 0.0, 100.0, 300.0));
        assert!(s.should_evict_class(SloClass::BestEffort, 0.0, 100.0, 300.0));
        assert!(!s.should_evict_class(SloClass::Critical, 0.0, 100.0, 300.0));
        // per-class charge equals the per-class trigger
        assert_eq!(s.eviction_charge_us_class(SloClass::BestEffort, 100.0), 200.0);
        assert_eq!(s.eviction_charge_us_class(SloClass::Standard, 100.0), 350.0);
        assert_eq!(
            s.eviction_charge_us_class(SloClass::Standard, 100.0),
            s.eviction_charge_us(100.0),
            "legacy charge is the Standard-class charge"
        );
    }
}
