//! SLO-aware OoO scheduling (§5.2): EDF base order, slack-driven
//! staggering, coalescing window, straggler eviction.
//!
//! The core tension the paper identifies: launching a ready kernel *now*
//! wastes the chance to coalesce with kernels arriving moments later, but
//! waiting burns SLO slack. The scheduler resolves it with a bounded
//! *coalescing window*: a pack is held while (a) every member still has
//! slack beyond the safety margin, and (b) the oldest member has waited
//! less than the window — "purposefully delays/staggers ill-fitting kernels
//! for better coalescing at a (slightly) later time" (§5).

use crate::compiler::coalescer::{Coalescer, SuperKernel};
use crate::compiler::window::Window;
use crate::gpu::kernel::KernelDesc;

/// Scheduling policy knobs.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Max artificial delay for coalescing, µs.
    pub coalesce_window_us: f64,
    /// Launch immediately once a pack reaches this many problems.
    pub target_pack: usize,
    /// Slack reserve: launch when `deadline − now − est` falls below this.
    pub safety_margin_us: f64,
    /// Evict an in-flight op when its runtime exceeds `eviction_factor ×`
    /// its estimate (§5.2 "simply evict degraded workers").
    pub eviction_factor: f64,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            coalesce_window_us: 2_000.0,
            target_pack: 4,
            safety_margin_us: 500.0,
            eviction_factor: 3.0,
        }
    }
}

/// A scheduling decision for the current instant.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Launch this superkernel now.
    Launch(SuperKernel),
    /// Nothing should launch before this time (stagger for coalescing).
    Wait {
        /// Re-evaluate at this time, µs.
        until_us: f64,
    },
    /// Window empty.
    Idle,
}

/// The OoO scheduler.
#[derive(Debug, Clone, Default)]
pub struct Scheduler {
    /// Policy knobs.
    pub policy: Policy,
    /// Packing rules.
    pub coalescer: Coalescer,
}

impl Scheduler {
    /// New scheduler.
    pub fn new(policy: Policy, coalescer: Coalescer) -> Self {
        Scheduler { policy, coalescer }
    }

    /// Decide what to do at time `now`. `est_exec` estimates a batched
    /// kernel's execution time (µs) — supplied by the executor's cost model
    /// so the scheduler stays backend-agnostic.
    pub fn decide<F>(&self, window: &Window, now: f64, est_exec: F) -> Decision
    where
        F: Fn(&KernelDesc) -> f64,
    {
        let mut ready = window.ready();
        if ready.is_empty() {
            return Decision::Idle;
        }
        // EDF base order (the OoO reordering step); ties broken by op id so
        // scheduling is fully deterministic (the window hands us ops in
        // hash-map order)
        ready.sort_by(|a, b| {
            a.deadline_us
                .partial_cmp(&b.deadline_us)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let packs = self.coalescer.pack(&ready);
        // priority pack = the one containing the globally earliest deadline
        let urgent_id = ready[0].id;
        let pack = packs
            .into_iter()
            .find(|p| p.ops.contains(&urgent_id))
            .expect("urgent op must be in some pack");

        // full pack: no reason to wait
        if pack.problems() >= self.policy.target_pack
            || pack.problems() >= self.coalescer.max_problems
        {
            return Decision::Launch(pack);
        }

        let est = est_exec(&pack.kernel);
        // latest safe launch time for the pack (tightest member)
        let critical_us = pack
            .ops
            .iter()
            .map(|id| window.get(*id).expect("pack member in window").deadline_us)
            .fold(f64::INFINITY, f64::min)
            - est
            - self.policy.safety_margin_us;
        // stagger budget: oldest member may wait at most coalesce_window
        let oldest_arrival = pack
            .ops
            .iter()
            .map(|id| window.get(*id).expect("member").arrival_us)
            .fold(f64::INFINITY, f64::min);
        let window_closes = oldest_arrival + self.policy.coalesce_window_us;

        let hold_until = critical_us.min(window_closes);
        if now >= hold_until {
            Decision::Launch(pack)
        } else {
            Decision::Wait {
                until_us: hold_until,
            }
        }
    }

    /// Straggler test (§5.2): should an op issued at `issued_us` with
    /// estimate `est_us` be evicted at `now`?
    pub fn should_evict(&self, issued_us: f64, est_us: f64, now: f64) -> bool {
        now - issued_us > self.policy.eviction_factor * est_us + 50.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::{DispatchRequest, StreamId};
    use crate::gpu::cost::CostModel;

    fn est(cm: &CostModel) -> impl Fn(&KernelDesc) -> f64 + '_ {
        move |k| cm.profile_default(k).duration_us
    }

    fn sched() -> Scheduler {
        Scheduler::new(Policy::default(), Coalescer::default())
    }

    fn submit(w: &mut Window, stream: u32, slo_us: f64, now: f64) {
        w.submit(
            DispatchRequest::new(
                StreamId(stream),
                KernelDesc::gemm(128, 512, 64),
                slo_us,
            ),
            now,
        )
        .unwrap();
    }

    #[test]
    fn idle_on_empty_window() {
        let w = Window::new(8);
        let cm = CostModel::v100();
        assert!(matches!(sched().decide(&w, 0.0, est(&cm)), Decision::Idle));
    }

    #[test]
    fn small_pack_with_slack_staggers() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 50_000.0, 0.0); // huge slack
        let cm = CostModel::v100();
        match sched().decide(&w, 0.0, est(&cm)) {
            Decision::Wait { until_us } => {
                assert!(until_us > 0.0 && until_us <= 2_000.0, "until={until_us}");
            }
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn critical_deadline_launches_immediately() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 600.0, 0.0); // slack ≈ safety margin
        let cm = CostModel::v100();
        match sched().decide(&w, 0.0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 1),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn full_pack_launches_without_waiting() {
        let mut w = Window::new(16);
        for s in 0..4 {
            submit(&mut w, s, 50_000.0, 0.0);
        }
        let cm = CostModel::v100();
        match sched().decide(&w, 0.0, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 4),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn wait_expires_at_window_close() {
        let mut w = Window::new(8);
        submit(&mut w, 0, 100_000.0, 0.0);
        let cm = CostModel::v100();
        let s = sched();
        // before window close: wait
        let until = match s.decide(&w, 100.0, est(&cm)) {
            Decision::Wait { until_us } => until_us,
            other => panic!("expected Wait, got {other:?}"),
        };
        // at/after the wait point: launch
        match s.decide(&w, until, est(&cm)) {
            Decision::Launch(p) => assert_eq!(p.problems(), 1),
            other => panic!("expected Launch, got {other:?}"),
        }
    }

    #[test]
    fn edf_orders_pack_priority() {
        let mut w = Window::new(8);
        // stream 0: relaxed; stream 1: tight and incompatible shape
        w.submit(
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(128, 512, 64), 90_000.0),
            0.0,
        )
        .unwrap();
        w.submit(
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(2048, 2048, 2048), 900.0),
            0.0,
        )
        .unwrap();
        let cm = CostModel::v100();
        // the urgent (big) op's pack must be chosen, not the relaxed one's
        match sched().decide(&w, 0.0, est(&cm)) {
            Decision::Launch(p) => {
                assert_eq!(p.kernel.m, 2048);
            }
            Decision::Wait { .. } => panic!("urgent op must launch"),
            Decision::Idle => unreachable!(),
        }
    }

    #[test]
    fn eviction_threshold() {
        let s = sched();
        assert!(!s.should_evict(0.0, 100.0, 200.0)); // 2x: fine
        assert!(s.should_evict(0.0, 100.0, 400.0)); // 4x: evict
    }
}
