//! The OoO VLIW JIT compiler — the paper's contribution (§5).
//!
//! Pipeline, mirroring Fig. 1:
//!
//! ```text
//!   streams of execution (declarative dispatch, §5.1)
//!        │ submit(TensorOp { kernel, stream, deadline })
//!        ▼
//!   [window]     OoO issue window: pending ops, per-stream program order,
//!                deadline bookkeeping
//!        ▼
//!   [scheduler]  SLO-aware reordering (§5.2): EDF base order, slack-driven
//!                *staggering* of ill-fitting kernels, coalescing window,
//!                straggler eviction
//!        ▼
//!   [coalescer]  VLIW packing (§5.3): shape classes, padding-overhead
//!                model, superkernel formation
//!        ▼
//!   [jit]        issue loop: launches superkernels on an executor
//!                (PJRT CPU or the V100 simulator); ops may carry a
//!                request payload (serving rows) and launches may run
//!                synchronously or fan out to worker threads
//! ```
//!
//! Ahead-of-time components: [`autotune`] (greedy vs collaborative blocking
//! configs, Table 1) and [`cluster`] (GEMM shape clustering, Fig. 7) feed
//! the runtime decisions, exactly as §5.3 prescribes ("our dynamic approach
//! uses both ahead-of-time tuning and runtime packing").

pub mod autotune;
pub mod cluster;
pub mod coalescer;
pub mod ir;
pub mod jit;
pub mod scheduler;
pub mod window;

pub use coalescer::{Coalescer, ShapeClass, SuperKernel};
pub use ir::{DispatchRequest, OpId, StreamId, TensorOp};
pub use jit::{
    JitCompiler, JitConfig, JitStats, KernelExecutor, LaunchRecord, PackExecutor,
    PackMember, PackRun, PendingLaunch,
};
pub use scheduler::{Decision, Policy, Scheduler};
pub use window::Window;
