//! The VLIW packer (§5.3): coalesce shape-compatible kernels from
//! independent streams into superkernels.
//!
//! Two ops coalesce when they quantize to the same [`ShapeClass`] — all
//! dimensions padded up to the class shape — and the padding overhead
//! (wasted FLOPs) stays under a configurable bound. The packed result is a
//! batched GEMM (`problems = Σ`), executed by the `cublasSgemmBatched`
//! analogue: the Pallas coalesced superkernel (real path) or a batched
//! [`KernelDesc`] (simulator path).

use std::collections::BTreeMap;

use crate::compiler::ir::{OpId, SloClass, TensorOp};
use crate::gpu::kernel::KernelDesc;

/// A quantized GEMM shape class: the grid the coalescer pads into.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeClass {
    /// Padded rows.
    pub m: u32,
    /// Padded contraction depth.
    pub k: u32,
    /// Padded columns.
    pub n: u32,
}

impl ShapeClass {
    /// Quantize a kernel to its class: each dim rounds up to the next power
    /// of two (GEMV-class ops keep m = 1 and coalesce along the problem
    /// dimension — the paper's RNN/LSTM case). Power-of-two quantization keeps the artifact set
    /// small (one AOT executable per class × capacity) at a bounded padding
    /// cost — at most 2× per dim, typically ≪ that within a Fig. 7 cluster.
    pub fn of(k: &KernelDesc) -> ShapeClass {
        fn q(d: u32) -> u32 {
            d.max(1).next_power_of_two()
        }
        ShapeClass {
            m: q(k.m),
            k: q(k.k),
            n: q(k.n),
        }
    }

    /// The padded per-problem kernel shape of this class.
    pub fn kernel(&self, problems: u32) -> KernelDesc {
        KernelDesc::batched(problems, self.m, self.k, self.n)
    }

    /// Fraction of FLOPs wasted when `k` is padded into this class
    /// (0 = perfect fit).
    pub fn padding_overhead(&self, k: &KernelDesc) -> f64 {
        let real = k.m as f64 * k.k as f64 * k.n as f64;
        let padded = self.m as f64 * self.k as f64 * self.n as f64;
        debug_assert!(padded >= real, "class must contain the kernel");
        1.0 - real / padded
    }
}

/// A packed superkernel: shape-compatible ops sharing one launch. Members
/// usually come from distinct streams; a stream's *independent* ops (the
/// window's ready prefix) may contribute several problems to one pack —
/// the serving layer's single-tenant burst case.
#[derive(Debug, Clone)]
pub struct SuperKernel {
    /// Shape class of the pack.
    pub class: ShapeClass,
    /// Member op ids, in pack order (problem index = position).
    pub ops: Vec<OpId>,
    /// Aggregate FLOPs actually requested (pre-padding).
    pub useful_flops: f64,
    /// The batched kernel to execute.
    pub kernel: KernelDesc,
}

impl SuperKernel {
    /// Number of coalesced problems.
    pub fn problems(&self) -> usize {
        self.ops.len()
    }

    /// Padding efficiency: useful FLOPs / launched FLOPs.
    pub fn pack_efficiency(&self) -> f64 {
        self.useful_flops / self.kernel.flops()
    }
}

/// Packing configuration.
#[derive(Debug, Clone)]
pub struct Coalescer {
    /// Max problems per superkernel (AOT artifact capacity ceiling).
    pub max_problems: usize,
    /// Reject pads wasting more than this FLOP fraction per op.
    pub max_padding: f64,
    /// Per-group pack-size caps (serving: a model's largest compiled batch
    /// variant). Groups without an entry use `max_problems`.
    pub group_caps: BTreeMap<u64, usize>,
}

impl Default for Coalescer {
    fn default() -> Self {
        Coalescer {
            max_problems: 8,
            max_padding: 0.75,
            group_caps: BTreeMap::new(),
        }
    }
}

impl Coalescer {
    /// New coalescer.
    pub fn new(max_problems: usize, max_padding: f64) -> Self {
        Coalescer {
            max_problems,
            max_padding,
            group_caps: BTreeMap::new(),
        }
    }

    /// Cap packs of `group` at `cap` problems (builder style).
    pub fn with_group_cap(mut self, group: u64, cap: usize) -> Self {
        self.group_caps.insert(group, cap);
        self
    }

    /// Effective pack-size cap for a group — the scheduler launches a pack
    /// that has reached this cap immediately (it can never grow further).
    pub fn cap_of(&self, group: u64) -> usize {
        self.group_caps
            .get(&group)
            .copied()
            .unwrap_or(self.max_problems)
            .min(self.max_problems)
            .max(1)
    }

    /// The `(group, SLO class, shape class)` bucket an op coalesces under.
    /// This is the ONE bucketing rule: [`Coalescer::pack`] and the
    /// incremental scheduler's persistent bucket mirror
    /// (`compiler/scheduler.rs`) both key on it, so batch packing and
    /// delta-maintained membership can never disagree. Ops whose padding
    /// overhead exceeds `max_padding` key under their *exact* shape (no
    /// quantization) — they only ever share a launch with identically
    /// shaped peers.
    pub fn bucket_key_of(&self, op: &TensorOp) -> (u64, SloClass, ShapeClass) {
        let class = ShapeClass::of(&op.kernel);
        if class.padding_overhead(&op.kernel) <= self.max_padding {
            (op.group, op.class, class)
        } else {
            // out-of-band shape: exact singleton class
            let exact = ShapeClass {
                m: op.kernel.m,
                k: op.kernel.k,
                n: op.kernel.n,
            };
            (op.group, op.class, exact)
        }
    }

    /// Group ready ops into superkernels.
    ///
    /// Greedy class-bucket packing: quantize every op, bucket by
    /// [`Coalescer::bucket_key_of`] — (coalescing group, SLO class, shape
    /// class) — and split buckets into chunks of the group's cap. SLO
    /// classes never share a launch — a best-effort pack can then be
    /// staggered, yielded, or evicted without dragging critical members
    /// along. Ops whose padding overhead exceeds `max_padding` go into
    /// singleton packs at their own (tighter) quantization. Input order is
    /// preserved inside a bucket so the scheduler's priority order (EDF)
    /// survives packing.
    ///
    /// # Determinism contract
    ///
    /// `pack` is a *pure function* of the input slice (order included):
    /// buckets live in a `BTreeMap`, so iteration order is a total order
    /// over keys, never hash- or allocation-dependent, and members keep
    /// their input order inside each bucket. Same window state ⇒ same
    /// packs ⇒ same scheduling decision — the property the incremental
    /// decide path's cached packs rely on (a cache keyed on anything
    /// nondeterministic would replay a *different* decision than a fresh
    /// repack), pinned by `pack_is_deterministic_across_calls` below and
    /// by the scheduler's naive-oracle property test.
    pub fn pack(&self, ops: &[&TensorOp]) -> Vec<SuperKernel> {
        let mut buckets: BTreeMap<(u64, SloClass, ShapeClass), Vec<&TensorOp>> = BTreeMap::new();
        for op in ops {
            buckets.entry(self.bucket_key_of(op)).or_default().push(op);
        }
        let mut packs = Vec::new();
        for ((group, _slo, class), members) in buckets {
            for chunk in members.chunks(self.cap_of(group)) {
                let useful: f64 = chunk.iter().map(|o| o.kernel.flops()).sum();
                packs.push(SuperKernel {
                    class,
                    ops: chunk.iter().map(|o| o.id).collect(),
                    useful_flops: useful,
                    kernel: class.kernel(chunk.len() as u32),
                });
            }
        }
        packs
    }

    /// Would these two kernels coalesce?
    pub fn compatible(&self, a: &KernelDesc, b: &KernelDesc) -> bool {
        let ca = ShapeClass::of(a);
        ca == ShapeClass::of(b)
            && ca.padding_overhead(a) <= self.max_padding
            && ca.padding_overhead(b) <= self.max_padding
    }
}

/// Rows of a pack that share a stream with an earlier row of the same pack
/// (0 = every member from a distinct stream). This is the launch-level
/// measure of stream-prefix coalescing: a single-tenant burst riding one
/// superkernel shows up here, singleton-per-stream packing stays at 0.
pub fn same_stream_rows(members: &[&TensorOp]) -> usize {
    let mut seen: Vec<crate::compiler::ir::StreamId> =
        Vec::with_capacity(members.len());
    let mut extra = 0;
    for op in members {
        if seen.contains(&op.stream) {
            extra += 1;
        } else {
            seen.push(op.stream);
        }
    }
    extra
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::StreamId;

    fn op(id: u64, stream: u32, m: u32, k: u32, n: u32) -> TensorOp {
        TensorOp {
            id: OpId(id),
            stream: StreamId(stream),
            seq: 0,
            kernel: KernelDesc::gemm(m, k, n),
            arrival_us: 0.0,
            deadline_us: 1e9,
            group: 0,
            tag: 0,
            independent: false,
            class: SloClass::Standard,
        }
    }

    #[test]
    fn quantization_rounds_up_pow2() {
        let c = ShapeClass::of(&KernelDesc::gemm(100, 576, 64));
        assert_eq!((c.m, c.k, c.n), (128, 1024, 64));
        // already pow2: unchanged
        let c2 = ShapeClass::of(&KernelDesc::gemm(128, 512, 64));
        assert_eq!((c2.m, c2.k, c2.n), (128, 512, 64));
        // GEMV-class ops keep m = 1 (they coalesce along the problem
        // dimension instead of padding rows)
        let c3 = ShapeClass::of(&KernelDesc::gemm(1, 3, 5));
        assert_eq!((c3.m, c3.k, c3.n), (1, 4, 8));
    }

    #[test]
    fn padding_overhead_bounds() {
        let k = KernelDesc::gemm(65, 512, 65);
        let c = ShapeClass::of(&k);
        let o = c.padding_overhead(&k);
        assert!(o > 0.0 && o < 0.75, "overhead={o}");
        let exact = KernelDesc::gemm(128, 512, 64);
        assert_eq!(ShapeClass::of(&exact).padding_overhead(&exact), 0.0);
    }

    #[test]
    fn same_class_ops_pack_together() {
        let a = op(0, 0, 120, 500, 60);
        let b = op(1, 1, 128, 512, 64);
        let c = op(2, 2, 100, 480, 50);
        let packs = Coalescer::default().pack(&[&a, &b, &c]);
        assert_eq!(packs.len(), 1);
        assert_eq!(packs[0].problems(), 3);
        assert_eq!(packs[0].kernel.problems, 3);
        assert!(packs[0].pack_efficiency() > 0.5);
    }

    #[test]
    fn different_classes_do_not_pack() {
        let a = op(0, 0, 128, 512, 64);
        let b = op(1, 1, 1024, 1024, 1024);
        let packs = Coalescer::default().pack(&[&a, &b]);
        assert_eq!(packs.len(), 2);
        assert!(packs.iter().all(|p| p.problems() == 1));
    }

    #[test]
    fn max_problems_splits_chunks() {
        let ops: Vec<TensorOp> = (0..10).map(|i| op(i, i as u32, 128, 512, 64)).collect();
        let refs: Vec<&TensorOp> = ops.iter().collect();
        let packs = Coalescer::new(4, 0.75).pack(&refs);
        let sizes: Vec<usize> = packs.iter().map(|p| p.problems()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn pack_order_preserves_input_priority() {
        // scheduler passes EDF order; the earliest-deadline op must be in
        // the first pack
        let a = op(7, 0, 128, 512, 64);
        let b = op(3, 1, 128, 512, 64);
        let packs = Coalescer::new(1, 0.75).pack(&[&a, &b]);
        assert_eq!(packs[0].ops, vec![OpId(7)]);
        assert_eq!(packs[1].ops, vec![OpId(3)]);
    }

    #[test]
    fn compatibility_check() {
        let c = Coalescer::default();
        assert!(c.compatible(
            &KernelDesc::gemm(120, 500, 60),
            &KernelDesc::gemm(128, 512, 64)
        ));
        assert!(!c.compatible(
            &KernelDesc::gemm(128, 512, 64),
            &KernelDesc::gemm(2048, 512, 64)
        ));
    }

    #[test]
    fn groups_do_not_pack_together() {
        // same shape class, different coalescing groups (two models whose
        // request shapes coincide): must stay in separate launches
        let mut a = op(0, 0, 128, 512, 64);
        let mut b = op(1, 1, 128, 512, 64);
        a.group = 1;
        b.group = 2;
        let packs = Coalescer::default().pack(&[&a, &b]);
        assert_eq!(packs.len(), 2);
        assert!(packs.iter().all(|p| p.problems() == 1));
    }

    #[test]
    fn slo_classes_do_not_pack_together() {
        // same group, same shape class, different SLO classes: a critical
        // op must never ride a best-effort launch (or vice versa) — the
        // eviction and yield rules act on whole packs
        let mut a = op(0, 0, 128, 512, 64);
        let mut b = op(1, 1, 128, 512, 64);
        let c = op(2, 2, 128, 512, 64);
        a.class = SloClass::Critical;
        b.class = SloClass::BestEffort;
        let packs = Coalescer::default().pack(&[&a, &b, &c]);
        assert_eq!(packs.len(), 3);
        assert!(packs.iter().all(|p| p.problems() == 1));
    }

    #[test]
    fn group_caps_bound_pack_size() {
        let ops: Vec<TensorOp> = (0..10)
            .map(|i| {
                let mut o = op(i, i as u32, 128, 512, 64);
                o.group = 5;
                o
            })
            .collect();
        let refs: Vec<&TensorOp> = ops.iter().collect();
        let packs = Coalescer::new(8, 0.75).with_group_cap(5, 3).pack(&refs);
        let sizes: Vec<usize> = packs.iter().map(|p| p.problems()).collect();
        assert_eq!(sizes, vec![3, 3, 3, 1]);
    }

    #[test]
    fn same_stream_ops_pack_into_one_superkernel() {
        // the window only exposes multiple ops of one stream when they are
        // independent; the packer must then coalesce them like any other
        // shape-compatible ops, preserving input (EDF) order
        let ops: Vec<TensorOp> = (0..4)
            .map(|i| {
                let mut o = op(i, 0, 128, 512, 64); // all stream 0
                o.seq = i;
                o.independent = true;
                o
            })
            .collect();
        let refs: Vec<&TensorOp> = ops.iter().collect();
        let packs = Coalescer::default().pack(&refs);
        assert_eq!(packs.len(), 1, "one burst, one launch");
        assert_eq!(packs[0].problems(), 4);
        assert_eq!(
            packs[0].ops,
            vec![OpId(0), OpId(1), OpId(2), OpId(3)],
            "input order survives packing"
        );
        assert_eq!(same_stream_rows(&refs), 3);
    }

    #[test]
    fn same_stream_rows_counts_extra_rows_only() {
        let a = op(0, 0, 128, 512, 64);
        let b = op(1, 1, 128, 512, 64);
        let c = op(2, 0, 128, 512, 64);
        let d = op(3, 2, 128, 512, 64);
        assert_eq!(same_stream_rows(&[&a, &b, &d]), 0, "all distinct streams");
        assert_eq!(same_stream_rows(&[&a, &b, &c, &d]), 1, "c repeats stream 0");
        assert_eq!(same_stream_rows(&[]), 0);
    }

    #[test]
    fn pack_is_deterministic_across_calls() {
        // determinism contract (see `pack` doc): identical input slices
        // must yield structurally identical pack lists, call after call —
        // no hash-order or allocation-address leakage into bucket order.
        // Mix of groups, SLO classes, shared shapes and an out-of-band
        // shape (padding overhead > max_padding keys under exact dims).
        let mut ops: Vec<TensorOp> = Vec::new();
        for i in 0..12u64 {
            let mut o = op(i, i as u32, 100 + (i as u32 % 3) * 9, 500, 60);
            o.group = i % 3;
            o.class = match i % 3 {
                0 => SloClass::Critical,
                1 => SloClass::Standard,
                _ => SloClass::BestEffort,
            };
            ops.push(o);
        }
        ops.push(op(99, 99, 1025, 1025, 1025)); // out of band: ~87% padding
        let refs: Vec<&TensorOp> = ops.iter().collect();
        let c = Coalescer::default();
        let a = c.pack(&refs);
        let b = c.pack(&refs);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "pack is not a pure function");
        // every member agrees with the shared bucketing rule the
        // incremental scheduler mirrors
        for p in &a {
            for id in &p.ops {
                let m = ops.iter().find(|o| o.id == *id).unwrap();
                let key = c.bucket_key_of(m);
                assert_eq!(key.2, p.class, "bucket_key_of disagrees with pack");
            }
        }
    }

    #[test]
    fn useful_flops_accounted() {
        let a = op(0, 0, 100, 500, 60);
        let b = op(1, 1, 128, 512, 64);
        let packs = Coalescer::default().pack(&[&a, &b]);
        let p = &packs[0];
        let expect = a.kernel.flops() + b.kernel.flops();
        assert!((p.useful_flops - expect).abs() < 1.0);
        assert!(p.kernel.flops() >= p.useful_flops);
    }
}
