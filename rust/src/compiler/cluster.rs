//! GEMM shape clustering (Fig. 7): "matrix multiply kernels from multiple
//! frequently used DNNs can be clustered by their dimensions. Within each
//! cluster, problems can be coalesced with minimal padding overhead."
//!
//! k-means in log-shape space (log2 m, log2 k, log2 n) over every GEMM in
//! the model zoo. The cluster centroids become the superkernel shape
//! classes the AOT pipeline compiles artifacts for.

use crate::gpu::kernel::KernelDesc;
use crate::util::rng::Rng;

/// A clustered set of GEMM shapes.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Centroid in log2 space (m, k, n).
    pub centroid: [f64; 3],
    /// Member kernels.
    pub members: Vec<KernelDesc>,
    /// Mean padding overhead if every member coalesces to the cluster's
    /// bounding power-of-two class.
    pub mean_padding: f64,
    /// The power-of-two shape class covering the members.
    pub class: (u32, u32, u32),
}

impl Cluster {
    /// Members count.
    pub fn size(&self) -> usize {
        self.members.len()
    }
}

fn feat(k: &KernelDesc) -> [f64; 3] {
    [
        (k.m.max(1) as f64).log2(),
        (k.k.max(1) as f64).log2(),
        (k.n.max(1) as f64).log2(),
    ]
}

fn dist2(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    (0..3).map(|i| (a[i] - b[i]) * (a[i] - b[i])).sum()
}

/// k-means over GEMM shapes. Deterministic (seeded k-means++ init), runs to
/// convergence or `max_iters`.
pub fn kmeans(kernels: &[KernelDesc], k: usize, seed: u64, max_iters: usize) -> Vec<Cluster> {
    assert!(k >= 1 && !kernels.is_empty());
    let k = k.min(kernels.len());
    let feats: Vec<[f64; 3]> = kernels.iter().map(feat).collect();
    let mut rng = Rng::new(seed);

    // k-means++ init
    let mut centroids: Vec<[f64; 3]> = Vec::with_capacity(k);
    centroids.push(feats[rng.below(feats.len() as u64) as usize]);
    while centroids.len() < k {
        let d2: Vec<f64> = feats
            .iter()
            .map(|f| {
                centroids
                    .iter()
                    .map(|c| dist2(f, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 1e-12 {
            // all points identical to existing centroids
            centroids.push(feats[rng.below(feats.len() as u64) as usize]);
            continue;
        }
        let mut u = rng.f64() * total;
        let mut pick = 0;
        for (i, d) in d2.iter().enumerate() {
            u -= d;
            if u <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.push(feats[pick]);
    }

    let mut assign = vec![0usize; feats.len()];
    for _ in 0..max_iters {
        // assign
        let mut changed = false;
        for (i, f) in feats.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(f, &centroids[a])
                        .partial_cmp(&dist2(f, &centroids[b]))
                        .unwrap()
                })
                .unwrap();
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        // update
        for c in 0..k {
            let mine: Vec<&[f64; 3]> = feats
                .iter()
                .zip(&assign)
                .filter(|(_, &a)| a == c)
                .map(|(f, _)| f)
                .collect();
            if mine.is_empty() {
                continue;
            }
            for d in 0..3 {
                centroids[c][d] =
                    mine.iter().map(|f| f[d]).sum::<f64>() / mine.len() as f64;
            }
        }
        if !changed {
            break;
        }
    }

    // materialize clusters
    (0..k)
        .filter_map(|c| {
            let members: Vec<KernelDesc> = kernels
                .iter()
                .zip(&assign)
                .filter(|(_, &a)| a == c)
                .map(|(kd, _)| *kd)
                .collect();
            if members.is_empty() {
                return None;
            }
            // representative pow2 class: centroid rounded up (what an AOT
            // artifact for this cluster would be compiled as)
            let class = (
                (centroids[c][0].exp2().ceil() as u32).next_power_of_two(),
                (centroids[c][1].exp2().ceil() as u32).next_power_of_two(),
                (centroids[c][2].exp2().ceil() as u32).next_power_of_two(),
            );
            // padding the *coalescer* actually pays: each member quantizes
            // to its own pow2 class (see compiler::coalescer::ShapeClass)
            let pad = |kd: &KernelDesc| {
                let q = |d: u32| d.max(1).next_power_of_two() as f64;
                1.0 - (kd.m as f64 * kd.k as f64 * kd.n as f64)
                    / (q(kd.m) * q(kd.k) * q(kd.n))
            };
            let mean_padding =
                members.iter().map(pad).sum::<f64>() / members.len() as f64;
            Some(Cluster {
                centroid: centroids[c],
                members,
                mean_padding,
                class,
            })
        })
        .collect()
}

/// Exact coalescing-class histogram: how many zoo kernels quantize to each
/// power-of-two [`crate::compiler::coalescer::ShapeClass`]. The size of a
/// class = the number of kernels that can ride the same superkernel
/// artifact — the direct measure of Fig. 7's "coalescing opportunity".
pub fn class_histogram(kernels: &[KernelDesc]) -> Vec<((u32, u32, u32), usize)> {
    use std::collections::BTreeMap;
    let mut h: BTreeMap<(u32, u32, u32), usize> = BTreeMap::new();
    for kd in kernels {
        let q = |d: u32| d.max(1).next_power_of_two();
        *h.entry((q(kd.m), q(kd.k), q(kd.n))).or_default() += 1;
    }
    let mut v: Vec<((u32, u32, u32), usize)> = h.into_iter().collect();
    v.sort_by(|a, b| b.1.cmp(&a.1));
    v
}

/// Within-cluster sum of squares (elbow metric / quality check).
pub fn wcss(clusters: &[Cluster]) -> f64 {
    clusters
        .iter()
        .map(|c| {
            c.members
                .iter()
                .map(|m| dist2(&feat(m), &c.centroid))
                .sum::<f64>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::zoo;

    fn zoo_gemms() -> Vec<KernelDesc> {
        zoo().iter().flat_map(|m| m.gemms(1)).collect()
    }

    #[test]
    fn clusters_cover_all_kernels() {
        let ks = zoo_gemms();
        let cs = kmeans(&ks, 6, 42, 50);
        let total: usize = cs.iter().map(|c| c.size()).sum();
        assert_eq!(total, ks.len());
        assert!(cs.len() <= 6 && !cs.is_empty());
    }

    #[test]
    fn deterministic_by_seed() {
        let ks = zoo_gemms();
        let a = kmeans(&ks, 5, 7, 50);
        let b = kmeans(&ks, 5, 7, 50);
        let sa: Vec<usize> = a.iter().map(|c| c.size()).collect();
        let sb: Vec<usize> = b.iter().map(|c| c.size()).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn zoo_shapes_concentrate_fig7() {
        // Fig. 7's claim: a handful of clusters captures most kernels with
        // small within-cluster spread
        let ks = zoo_gemms();
        let c6 = kmeans(&ks, 6, 42, 100);
        let c1 = kmeans(&ks, 1, 42, 100);
        assert!(
            wcss(&c6) < 0.35 * wcss(&c1),
            "6 clusters must explain >65% of shape variance: {} vs {}",
            wcss(&c6),
            wcss(&c1)
        );
        // top-3 clusters hold the majority of kernels
        let mut sizes: Vec<usize> = c6.iter().map(|c| c.size()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let top3: usize = sizes.iter().take(3).sum();
        assert!(top3 * 2 > ks.len(), "top3={top3} of {}", ks.len());
    }

    #[test]
    fn more_clusters_reduce_wcss() {
        let ks = zoo_gemms();
        let w2 = wcss(&kmeans(&ks, 2, 1, 100));
        let w8 = wcss(&kmeans(&ks, 8, 1, 100));
        assert!(w8 < w2);
    }

    #[test]
    fn padding_overhead_is_bounded() {
        let ks = zoo_gemms();
        for c in kmeans(&ks, 8, 42, 100) {
            assert!(
                (0.0..1.0).contains(&c.mean_padding),
                "padding {}",
                c.mean_padding
            );
        }
    }

    #[test]
    fn class_histogram_concentrates() {
        // Fig. 7: a few classes dominate => big coalescing opportunity
        let ks = zoo_gemms();
        let h = class_histogram(&ks);
        assert!(!h.is_empty());
        let total: usize = h.iter().map(|(_, n)| n).sum();
        assert_eq!(total, ks.len());
        let top10: usize = h.iter().take(10).map(|(_, n)| n).sum();
        assert!(
            top10 * 2 > total,
            "top-10 classes must cover >50%: {top10}/{total}"
        );
        // histogram is sorted descending
        assert!(h.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn single_point_cluster() {
        let ks = vec![KernelDesc::gemm(64, 64, 64)];
        let cs = kmeans(&ks, 3, 0, 10);
        assert_eq!(cs.iter().map(|c| c.size()).sum::<usize>(), 1);
        let c = cs.iter().find(|c| c.size() == 1).unwrap();
        assert_eq!(c.class, (64, 64, 64));
        assert!(c.mean_padding.abs() < 1e-12);
    }
}
