//! The Measured tier: a keyed bank of per-variant EWMAs.
//!
//! Generic over the key so the same learning substrate serves both the
//! serving estimator ([`super::VariantKey`]) and the artifact-level
//! runtime executor (keyed by compiled-artifact file). This is the only
//! place outside `util/stats.rs` that constructs an [`Ewma`]; every
//! consumer goes through [`super::TieredEstimator`] or this bank.

use std::collections::HashMap;
use std::hash::Hash;

use crate::util::stats::Ewma;

/// A bank of EWMAs keyed by variant identity. Unobserved keys answer
/// `None`; callers fall back to their next tier.
#[derive(Debug, Clone)]
pub struct Measured<K> {
    alpha: f64,
    ewmas: HashMap<K, Ewma>,
}

impl<K: Eq + Hash + Clone> Measured<K> {
    /// Empty bank with smoothing factor `alpha` in (0, 1] (see
    /// `Policy::ewma_alpha` for the serving default and rationale).
    pub fn new(alpha: f64) -> Self {
        Measured {
            alpha,
            ewmas: HashMap::new(),
        }
    }

    /// The smoothing factor new keys are created with.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Change the smoothing factor for keys observed *from now on*
    /// (existing EWMAs keep the alpha they were created with).
    pub fn set_alpha(&mut self, alpha: f64) {
        self.alpha = alpha;
    }

    /// Fold one observation into `key`'s EWMA (creating it on first use).
    pub fn observe(&mut self, key: K, us: f64) {
        self.ewmas
            .entry(key)
            .or_insert_with(|| Ewma::new(self.alpha))
            .observe(us);
    }

    /// Current estimate for `key`, or `None` if never observed.
    pub fn get(&self, key: &K) -> Option<f64> {
        self.ewmas.get(key).and_then(|e| e.value())
    }

    /// Observations folded into `key` so far (0 if never observed).
    pub fn count(&self, key: &K) -> u64 {
        self.ewmas.get(key).map(|e| e.count()).unwrap_or(0)
    }

    /// Number of distinct observed keys.
    pub fn len(&self) -> usize {
        self.ewmas.len()
    }

    /// True when no key has been observed.
    pub fn is_empty(&self) -> bool {
        self.ewmas.is_empty()
    }

    /// Iterate (key, estimate, observation count) over observed keys.
    /// Iteration order is unspecified (HashMap) — callers that need
    /// determinism must sort (see `TieredEstimator::hottest`).
    pub fn iter(&self) -> impl Iterator<Item = (&K, f64, u64)> {
        self.ewmas
            .iter()
            .filter_map(|(k, e)| e.value().map(|v| (k, v, e.count())))
    }

    /// Measured estimate for `key`, or the caller's fallback.
    pub fn estimate_or(&self, key: &K, fallback: impl FnOnce() -> f64) -> f64 {
        self.get(key).unwrap_or_else(fallback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unobserved_key_falls_back() {
        let m: Measured<&str> = Measured::new(0.3);
        assert_eq!(m.get(&"a"), None);
        assert_eq!(m.count(&"a"), 0);
        assert_eq!(m.estimate_or(&"a", || 42.0), 42.0);
    }

    #[test]
    fn keys_are_isolated() {
        let mut m: Measured<u32> = Measured::new(0.5);
        m.observe(1, 100.0);
        m.observe(2, 900.0);
        assert_eq!(m.get(&1), Some(100.0));
        assert_eq!(m.get(&2), Some(900.0));
        m.observe(1, 200.0);
        assert_eq!(m.get(&1), Some(150.0));
        assert_eq!(m.get(&2), Some(900.0), "key 2 untouched by key 1");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn zero_observation_is_a_real_estimate() {
        let mut m: Measured<u32> = Measured::new(0.3);
        m.observe(7, 0.0);
        assert_eq!(m.get(&7), Some(0.0));
        assert_eq!(m.estimate_or(&7, || 999.0), 0.0);
    }
}
