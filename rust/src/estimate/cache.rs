//! The Tuned tier's persistence: the autotune artifact cache.
//!
//! A [`TunedCache`] is the on-disk form of the Tuned tier — tuned
//! duration estimates keyed by (model, device class, padded batch), with
//! the power-of-two shape class recorded as provenance. See the module
//! doc of [`crate::estimate`] for the file format contract.

use std::collections::BTreeMap;
use std::path::Path;

use crate::compiler::coalescer::ShapeClass;
use crate::gpu::kernel::KernelDesc;
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Power-of-two shape-class provenance string (`MxKxN`) for a kernel,
/// via [`ShapeClass::of`] — the Fig. 7 clustering quantization.
pub fn shape_class_label(k: &KernelDesc) -> String {
    let c = ShapeClass::of(k);
    format!("{}x{}x{}", c.m, c.k, c.n)
}

/// One cached tuned estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedEntry {
    /// Shape-class provenance (`MxKxN`, pow2-quantized). Informational:
    /// lookup keys on the exact padded batch, not the class.
    pub class: String,
    /// Tuned duration estimate, µs.
    pub est_us: f64,
}

/// Persistent tuned-estimate cache: (model, device, batch) → entry.
///
/// `BTreeMap` keys give deterministic serialization order, so saving the
/// same logical cache always produces byte-identical files.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TunedCache {
    entries: BTreeMap<(String, String, u32), TunedEntry>,
}

impl TunedCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert/overwrite the entry for (model, device, batch).
    pub fn insert(&mut self, model: &str, device: &str, batch: u32, entry: TunedEntry) {
        self.entries
            .insert((model.to_string(), device.to_string(), batch), entry);
    }

    /// Tuned estimate for (model, device, batch), if cached.
    pub fn get(&self, model: &str, device: &str, batch: u32) -> Option<f64> {
        self.entries
            .get(&(model.to_string(), device.to_string(), batch))
            .map(|e| e.est_us)
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate ((model, device, batch), entry) in deterministic key order.
    pub fn iter(&self) -> impl Iterator<Item = (&(String, String, u32), &TunedEntry)> {
        self.entries.iter()
    }

    /// Merge `other` into `self` (other's entries win on key collision).
    pub fn merge(&mut self, other: &TunedCache) {
        for (k, v) in &other.entries {
            self.entries.insert(k.clone(), v.clone());
        }
    }

    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|((model, device, batch), e)| {
                obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("class", Json::Str(e.class.clone())),
                    ("device", Json::Str(device.clone())),
                    ("batch", Json::Num(*batch as f64)),
                    ("est_us", Json::Num(e.est_us)),
                ])
            })
            .collect();
        obj(vec![
            ("version", Json::Num(1.0)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse from the versioned JSON document.
    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j.req_u64("version")?;
        if version != 1 {
            return Err(Error::Json(format!(
                "tuned cache version {version} unsupported (want 1)"
            )));
        }
        let mut cache = TunedCache::new();
        let entries = j
            .req("entries")?
            .as_arr()
            .ok_or_else(|| Error::Json("field 'entries' not an array".into()))?;
        for e in entries {
            let model = e.req_str("model")?;
            let device = e.req_str("device")?;
            let batch = e.req_u64("batch")? as u32;
            let entry = TunedEntry {
                class: e.req_str("class")?,
                est_us: e.req_f64("est_us")?,
            };
            cache.insert(&model, &device, batch, entry);
        }
        Ok(cache)
    }

    /// Write the cache to `path` (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    /// Load a cache from `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TunedCache {
        let mut c = TunedCache::new();
        c.insert(
            "mlp_small",
            "v100",
            8,
            TunedEntry {
                class: "8x64x64".into(),
                est_us: 812.5,
            },
        );
        c.insert(
            "mlp_small",
            "t4",
            8,
            TunedEntry {
                class: "8x64x64".into(),
                est_us: 1625.0,
            },
        );
        c.insert(
            "gemmnet6",
            "v100",
            4,
            TunedEntry {
                class: "4x512x64".into(),
                est_us: 90.0,
            },
        );
        c
    }

    #[test]
    fn lookup_keys_on_model_device_batch() {
        let c = sample();
        assert_eq!(c.get("mlp_small", "v100", 8), Some(812.5));
        assert_eq!(c.get("mlp_small", "t4", 8), Some(1625.0));
        assert_eq!(c.get("mlp_small", "v100", 4), None, "batch is exact");
        assert_eq!(c.get("mlp_small", "k80", 8), None, "device is exact");
        assert_eq!(c.get("absent", "v100", 8), None);
    }

    #[test]
    fn json_round_trip_is_lossless_and_deterministic() {
        let c = sample();
        let text = c.to_json().to_string_compact();
        let back = TunedCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
        // deterministic serialization: same cache, same bytes
        assert_eq!(back.to_json().to_string_compact(), text);
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.req_u64("version").unwrap(), 1);
        assert_eq!(doc.req("entries").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn save_load_round_trip() {
        let c = sample();
        let path = std::env::temp_dir().join("vliw_tuned_cache_test.json");
        c.save(&path).unwrap();
        let back = TunedCache::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, c);
    }

    #[test]
    fn version_mismatch_is_an_error() {
        let j = Json::parse(r#"{"version": 2, "entries": []}"#).unwrap();
        assert!(TunedCache::from_json(&j).is_err());
    }

    #[test]
    fn merge_overwrites_on_collision() {
        let mut a = sample();
        let mut b = TunedCache::new();
        b.insert(
            "mlp_small",
            "v100",
            8,
            TunedEntry {
                class: "8x64x64".into(),
                est_us: 700.0,
            },
        );
        a.merge(&b);
        assert_eq!(a.get("mlp_small", "v100", 8), Some(700.0));
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn shape_class_label_is_pow2() {
        let k = KernelDesc::gemm(6, 48, 64);
        assert_eq!(shape_class_label(&k), "8x64x64");
    }
}
