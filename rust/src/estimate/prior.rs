//! The Prior tier: analytic fallback pricing.
//!
//! The one sanctioned path from the serving/scheduling layers to the
//! [`crate::gpu::cost`] roofline model for *duration pricing*. Keeping
//! the call here (instead of at each consumer) makes the acceptance
//! criterion grep-enforceable: nothing outside `rust/src/estimate/`
//! prices a launch against `cost.rs` directly.

use crate::gpu::cost::CostModel;
use crate::gpu::kernel::{KernelDesc, LaunchConfig};

/// Analytic isolated duration (µs) of `k` under `cfg` on `cm`'s device —
/// the roofline + wave-quantization model's `duration_us`.
pub fn analytic_us(cm: &CostModel, cfg: &LaunchConfig, k: &KernelDesc) -> f64 {
    cm.profile(k, cfg).duration_us
}

/// Analytic duration scaled onto a device class running at
/// `class_speed` × the modeled device (the Prior-tier contract:
/// analytic model divided by device-class speed).
pub fn analytic_on_class_us(
    cm: &CostModel,
    cfg: &LaunchConfig,
    k: &KernelDesc,
    class_speed: f64,
) -> f64 {
    analytic_us(cm, cfg, k) / class_speed.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_matches_cost_model_and_scales_by_speed() {
        let cm = CostModel::v100();
        let cfg = LaunchConfig::greedy();
        let k = KernelDesc::gemm(64, 512, 64);
        let base = analytic_us(&cm, &cfg, &k);
        assert_eq!(base, cm.profile(&k, &cfg).duration_us);
        let half = analytic_on_class_us(&cm, &cfg, &k, 0.5);
        assert!((half - 2.0 * base).abs() < 1e-9, "half-speed doubles");
    }
}
