//! One cost model — the tiered duration estimator.
//!
//! Every decision the OoO JIT makes (coalesce, hold, dispatch, evict,
//! admit, place) is priced against a latency estimate. Before this module
//! those estimates lived in disconnected layers: analytic roofline numbers
//! in [`crate::gpu::cost`], per-variant EWMAs inside the serving executor,
//! and fallback chains re-derived at each call site. Now "what does this
//! launch cost on this device" has exactly one answer: a
//! [`TieredEstimator`] query, resolved through three tiers with explicit
//! provenance.
//!
//! ## The tier contract
//!
//! A *variant* is a [`VariantKey`] — (device class, coalescing group,
//! padded batch). Queries resolve strictly top-down:
//!
//! 1. **[`Tier::Measured`]** — a live EWMA fed by completed launches on
//!    that exact variant (same (class, group, padded-batch) isolation the
//!    serving layer has always had: a t4 observation never updates a v100
//!    estimate). Once a variant has *one* measured observation this tier
//!    answers **forever** — Tuned and Prior are never consulted for it
//!    again (pinned by the tier-monotonicity property test in
//!    [`tiered`]).
//! 2. **[`Tier::Tuned`]** — a warm-start value from the persistent
//!    autotune artifact cache ([`TunedCache`]), loaded at server start so
//!    serving prices realistically *before any observation lands*. A
//!    background refinement hook writes the hottest measured variants
//!    back into this tier (and thus into the cache file on save), so the
//!    next cold start inherits this run's learning.
//! 3. **[`Tier::Prior`]** — the caller-supplied analytic fallback
//!    (backend FLOPs / device GFLOP/s, or the [`crate::gpu::cost`]
//!    roofline via [`prior::analytic_us`]), divided by device-class
//!    speed. Always available, never trusted once anything better exists.
//!
//! The estimator is the *only* place allowed to construct an
//! [`crate::util::stats::Ewma`] for launch pricing or to consult the
//! analytic model for a serving-path duration (grep-enforceable: no
//! `Ewma::new` and no `cost.rs` timing calls for pricing outside
//! `rust/src/estimate/`).
//!
//! Every query also bumps a per-tier hit counter and every observation
//! records |predicted − actual| into an estimate-error histogram — both
//! surface through [`EstimatorStats`] into `ServeMetrics` and the bench
//! JSON, so estimator fidelity is tracked across PRs.
//!
//! ## Cache file format (`artifacts/tuned.json`)
//!
//! Written by `vliwd autotune --save` and by serving on exit; loaded by
//! `vliwd serve` / `vliwd bench --warm-start` at startup:
//!
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {"model": "mlp_small", "class": "8x64x64",
//!      "device": "v100", "batch": 8, "est_us": 812.5}
//!   ]
//! }
//! ```
//!
//! * `model` — model/group name (the coalescing group identity).
//! * `class` — power-of-two shape-class provenance string `MxKxN` from
//!   [`crate::compiler::coalescer::ShapeClass`] (the Fig. 7 clustering
//!   quantization); informational — lookup keys on the exact padded
//!   batch so two batches sharing a pow2 class never collide.
//! * `device` — device-class name from [`crate::gpu::device::DeviceSpec`]
//!   (`v100`, `t4`, …); an entry only warm-starts fleets that actually
//!   contain that class.
//! * `batch` — the padded batch size of the compiled variant.
//! * `est_us` — the tuned duration estimate in microseconds.
//!
//! Entries are keyed (model, device, batch); re-saving a cache after a
//! serve run overwrites stale entries with refined ones and keeps
//! entries for devices the run never saw.

pub mod cache;
pub mod measured;
pub mod prior;
pub mod tiered;

pub use cache::{shape_class_label, TunedCache, TunedEntry};
pub use measured::Measured;
pub use tiered::TieredEstimator;

use crate::util::stats::LatencyHist;

/// Which tier answered (or would answer) a duration query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Live EWMA over completed launches of this exact variant.
    Measured,
    /// Warm-start value from the persistent autotune artifact cache.
    Tuned,
    /// Analytic fallback (backend prior ÷ device-class speed).
    Prior,
}

/// Identity of one priced variant: the (device class, coalescing group,
/// padded batch) triple every estimate and observation is keyed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VariantKey {
    /// Device-class index within the fleet topology.
    pub class: u32,
    /// Coalescing-group id (one model = one group).
    pub group: u64,
    /// Padded batch size of the compiled variant.
    pub padded: u32,
}

/// The one duration-pricing interface every consumer goes through.
///
/// `estimate_us` takes the Prior tier as a *lazy* closure so callers only
/// pay the analytic model when both learned tiers miss; `observe` takes
/// the prior eagerly (it is needed to score prediction error even when a
/// learned tier exists, and an eager `f64` keeps the mutable-borrow
/// surface trivial for callers that compute the prior from `&self`).
pub trait Estimator {
    /// Price a variant: Measured, else Tuned, else `prior()`.
    fn estimate_us(&self, key: VariantKey, prior: &dyn Fn() -> f64) -> f64;

    /// Which tier would answer `estimate_us` right now (no counter bump).
    fn tier_of(&self, key: VariantKey) -> Tier;

    /// Fold in one completed-launch duration. `prior_us` is the Prior-tier
    /// value for this variant, used to score prediction error when no
    /// learned tier existed yet.
    fn observe(&mut self, key: VariantKey, us: f64, prior_us: f64);
}

/// Estimator fidelity counters, copied into `ServeMetrics` at end of run.
#[derive(Debug, Clone, Default)]
pub struct EstimatorStats {
    /// Queries answered by the Measured tier.
    pub measured_hits: u64,
    /// Queries answered by the Tuned (warm-start cache) tier.
    pub tuned_hits: u64,
    /// Queries that fell through to the analytic Prior.
    pub prior_hits: u64,
    /// |predicted − actual| µs per completed launch.
    pub est_err: LatencyHist,
}

impl EstimatorStats {
    /// Total queries across all tiers.
    pub fn total_hits(&self) -> u64 {
        self.measured_hits + self.tuned_hits + self.prior_hits
    }
}
