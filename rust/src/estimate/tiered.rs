//! The [`TieredEstimator`]: Measured → Tuned → Prior resolution with
//! per-tier hit accounting, a prediction-error histogram, a tier-change
//! generation counter (so published admission views know when to
//! refresh), and the background refinement hook that writes the hottest
//! measured variants back into the Tuned tier.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};

use super::measured::Measured;
use super::{Estimator, EstimatorStats, Tier, VariantKey};
use crate::util::stats::LatencyHist;

/// Default observation interval between refinement passes.
pub const REFINE_PERIOD: u64 = 64;
/// Default number of hottest variants promoted per refinement pass.
pub const REFINE_TOP: usize = 8;
/// Default prediction-error p99 (µs) above which the refinement cadence
/// tightens (see [`TieredEstimator::effective_refine_period`]).
pub const REFINE_ERR_THRESHOLD_US: f64 = 500.0;

/// Error samples required before the error-driven cadence change engages.
const ADAPT_MIN_ERR_SAMPLES: u64 = 16;
/// Tier hits required before the measured-dominance backoff engages.
const ADAPT_MIN_HITS: u64 = 64;

/// The three-tier duration estimator. See the [`crate::estimate`] module
/// doc for the tier contract.
///
/// Hit counters are atomics because pricing (`estimate_us`) runs behind
/// `&self` from every consumer; `Relaxed` is enough — they are
/// monotonically-increasing telemetry, never synchronization.
#[derive(Debug)]
pub struct TieredEstimator {
    measured: Measured<VariantKey>,
    tuned: HashMap<VariantKey, f64>,
    measured_hits: AtomicU64,
    tuned_hits: AtomicU64,
    prior_hits: AtomicU64,
    /// Bumped whenever the answer to some `estimate_us` query changes for
    /// a reason other than an EWMA update on an already-Measured variant:
    /// a variant's *first* measurement (Tuned/Prior → Measured) or a warm
    /// start landing on an unmeasured variant. Consumers that memoize
    /// estimates (the published `AdmissionView` tables) re-derive when
    /// this moves.
    generation: AtomicU64,
    err_hist: LatencyHist,
    refine_period: u64,
    refine_top: usize,
    refine_err_threshold_us: f64,
    obs_since_refine: u64,
}

impl Clone for TieredEstimator {
    fn clone(&self) -> Self {
        TieredEstimator {
            measured: self.measured.clone(),
            tuned: self.tuned.clone(),
            measured_hits: AtomicU64::new(self.measured_hits.load(Ordering::Relaxed)),
            tuned_hits: AtomicU64::new(self.tuned_hits.load(Ordering::Relaxed)),
            prior_hits: AtomicU64::new(self.prior_hits.load(Ordering::Relaxed)),
            generation: AtomicU64::new(self.generation.load(Ordering::Relaxed)),
            err_hist: self.err_hist.clone(),
            refine_period: self.refine_period,
            refine_top: self.refine_top,
            refine_err_threshold_us: self.refine_err_threshold_us,
            obs_since_refine: self.obs_since_refine,
        }
    }
}

impl TieredEstimator {
    /// Empty estimator; `alpha` is the Measured-tier EWMA smoothing
    /// factor (`Policy::ewma_alpha`).
    pub fn new(alpha: f64) -> Self {
        TieredEstimator {
            measured: Measured::new(alpha),
            tuned: HashMap::new(),
            measured_hits: AtomicU64::new(0),
            tuned_hits: AtomicU64::new(0),
            prior_hits: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            err_hist: LatencyHist::new(),
            refine_period: REFINE_PERIOD,
            refine_top: REFINE_TOP,
            refine_err_threshold_us: REFINE_ERR_THRESHOLD_US,
            obs_since_refine: 0,
        }
    }

    /// Measured-tier smoothing factor for keys observed from now on.
    pub fn set_alpha(&mut self, alpha: f64) {
        self.measured.set_alpha(alpha);
    }

    /// Configure the background refinement cadence (observations between
    /// passes, variants promoted per pass). `period = 0` disables it.
    pub fn set_refine(&mut self, period: u64, top: usize) {
        self.refine_period = period;
        self.refine_top = top;
    }

    /// Prediction-error p99 (µs) above which refinement tightens
    /// (`Policy::refine_err_threshold_us`).
    pub fn set_refine_err_threshold_us(&mut self, threshold_us: f64) {
        self.refine_err_threshold_us = threshold_us;
    }

    /// The refinement period actually in force, adapted to estimator
    /// fidelity: while the prediction-error p99 exceeds the threshold the
    /// base period quarters (mispriced variants reach the persistable
    /// Tuned tier sooner); once the Measured tier answers the dominant
    /// share (> 80%) of queries *and* the error p99 is back under the
    /// threshold, the period stretches 4× — a converged estimator has
    /// little left to promote. In between (or before enough samples
    /// accumulate) the base period applies. Error wins over dominance:
    /// a measured-dominated estimator that is still mispricing keeps the
    /// tight cadence.
    pub fn effective_refine_period(&self) -> u64 {
        if self.refine_period == 0 {
            return 0;
        }
        let err_high = self.err_hist.count() >= ADAPT_MIN_ERR_SAMPLES
            && self.err_hist.quantile_us(0.99) > self.refine_err_threshold_us;
        if err_high {
            return (self.refine_period / 4).max(1);
        }
        let measured = self.measured_hits.load(Ordering::Relaxed);
        let total = measured
            + self.tuned_hits.load(Ordering::Relaxed)
            + self.prior_hits.load(Ordering::Relaxed);
        if total >= ADAPT_MIN_HITS && measured * 5 > total * 4 {
            return self.refine_period.saturating_mul(4);
        }
        self.refine_period
    }

    /// Warm-start the Tuned tier for one variant (from a loaded
    /// [`super::TunedCache`]). Bumps the generation only when this
    /// actually changes some query's answer — i.e. the variant is not
    /// already Measured and the value is new.
    pub fn warm(&mut self, key: VariantKey, est_us: f64) {
        let prev = self.tuned.insert(key, est_us);
        if self.measured.count(&key) == 0 && prev != Some(est_us) {
            self.generation.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current Measured-tier value, if any.
    pub fn measured_value(&self, key: VariantKey) -> Option<f64> {
        self.measured.get(&key)
    }

    /// Current Tuned-tier value, if any.
    pub fn tuned_value(&self, key: VariantKey) -> Option<f64> {
        self.tuned.get(&key).copied()
    }

    /// Hottest measured variants: (key, estimate, observations), sorted
    /// by observation count descending, key ascending (deterministic).
    pub fn hottest(&self, k: usize) -> Vec<(VariantKey, f64, u64)> {
        let mut v: Vec<(VariantKey, f64, u64)> = self
            .measured
            .iter()
            .map(|(key, val, n)| (*key, val, n))
            .collect();
        v.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// The background refinement hook: promote the `k` hottest measured
    /// variants' current estimates into the Tuned tier, so a subsequent
    /// cache export (and the next cold start) inherits them. Never
    /// changes a live answer (Measured still wins for those variants)
    /// and never bumps the generation. Returns how many entries changed.
    pub fn refine_hottest(&mut self, k: usize) -> usize {
        let mut changed = 0;
        for (key, val, _) in self.hottest(k) {
            if self.tuned.get(&key) != Some(&val) {
                self.tuned.insert(key, val);
                changed += 1;
            }
        }
        changed
    }

    /// Deterministic export of everything the learned tiers know:
    /// (key, value, tier) sorted by key, Measured values shadowing Tuned
    /// ones for the same variant.
    pub fn export(&self) -> Vec<(VariantKey, f64, Tier)> {
        let mut out: BTreeMap<VariantKey, (f64, Tier)> = BTreeMap::new();
        for (key, val) in &self.tuned {
            out.insert(*key, (*val, Tier::Tuned));
        }
        for (key, val, _) in self.measured.iter() {
            out.insert(*key, (val, Tier::Measured));
        }
        out.into_iter().map(|(k, (v, t))| (k, v, t)).collect()
    }

    /// Snapshot of the fidelity counters + error histogram.
    pub fn stats(&self) -> EstimatorStats {
        EstimatorStats {
            measured_hits: self.measured_hits.load(Ordering::Relaxed),
            tuned_hits: self.tuned_hits.load(Ordering::Relaxed),
            prior_hits: self.prior_hits.load(Ordering::Relaxed),
            est_err: self.err_hist.clone(),
        }
    }

    /// Tier-change generation (see the field doc).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }
}

impl Estimator for TieredEstimator {
    fn estimate_us(&self, key: VariantKey, prior: &dyn Fn() -> f64) -> f64 {
        if let Some(v) = self.measured.get(&key) {
            self.measured_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        if let Some(&v) = self.tuned.get(&key) {
            self.tuned_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        self.prior_hits.fetch_add(1, Ordering::Relaxed);
        prior()
    }

    fn tier_of(&self, key: VariantKey) -> Tier {
        if self.measured.get(&key).is_some() {
            Tier::Measured
        } else if self.tuned.contains_key(&key) {
            Tier::Tuned
        } else {
            Tier::Prior
        }
    }

    fn observe(&mut self, key: VariantKey, us: f64, prior_us: f64) {
        let predicted = self
            .measured
            .get(&key)
            .or_else(|| self.tuned.get(&key).copied())
            .unwrap_or(prior_us);
        self.err_hist.record_us((predicted - us).abs());
        let first = self.measured.count(&key) == 0;
        self.measured.observe(key, us);
        if first {
            self.generation.fetch_add(1, Ordering::Relaxed);
        }
        if self.refine_period > 0 {
            self.obs_since_refine += 1;
            if self.obs_since_refine >= self.effective_refine_period() {
                self.obs_since_refine = 0;
                self.refine_hottest(self.refine_top);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(class: u32, group: u64, padded: u32) -> VariantKey {
        VariantKey {
            class,
            group,
            padded,
        }
    }

    #[test]
    fn tiers_resolve_top_down_with_hit_counters() {
        let mut e = TieredEstimator::new(0.3);
        let k = key(0, 0, 8);
        let prior = || 1000.0;

        assert_eq!(e.tier_of(k), Tier::Prior);
        assert_eq!(e.estimate_us(k, &prior), 1000.0);

        e.warm(k, 800.0);
        assert_eq!(e.tier_of(k), Tier::Tuned);
        assert_eq!(e.estimate_us(k, &prior), 800.0);

        e.observe(k, 600.0, prior());
        assert_eq!(e.tier_of(k), Tier::Measured);
        assert_eq!(e.estimate_us(k, &prior), 600.0);

        let s = e.stats();
        assert_eq!(
            (s.measured_hits, s.tuned_hits, s.prior_hits),
            (1, 1, 1),
            "one hit per tier in query order"
        );
        assert_eq!(s.total_hits(), 3);
    }

    #[test]
    fn measured_tier_never_consults_prior_closure() {
        let mut e = TieredEstimator::new(0.3);
        let k = key(1, 2, 4);
        e.observe(k, 500.0, 100.0);
        let v = e.estimate_us(k, &|| panic!("prior consulted for a measured variant"));
        assert_eq!(v, 500.0);
    }

    /// Property: once a variant is Measured, Tuned/Prior are never
    /// consulted for it again — under any interleaving of observations,
    /// warm starts, and queries across a small key space.
    #[test]
    fn prop_tier_is_monotone_once_measured() {
        let mut e = TieredEstimator::new(0.3);
        let mut rng: u64 = 0x5eed_cafe;
        let mut next = move || {
            rng = rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            rng >> 33
        };
        let keys: Vec<VariantKey> = (0..2u32)
            .flat_map(|c| (0..3u64).flat_map(move |g| [key(c, g, 4), key(c, g, 8)]))
            .collect();
        let mut measured_keys: Vec<VariantKey> = Vec::new();

        for _ in 0..2000 {
            let k = keys[(next() as usize) % keys.len()];
            match next() % 3 {
                0 => {
                    let us = 100.0 + (next() % 1000) as f64;
                    e.observe(k, us, 50.0);
                    if !measured_keys.contains(&k) {
                        measured_keys.push(k);
                    }
                }
                1 => e.warm(k, 10.0 + (next() % 500) as f64),
                _ => {
                    let _ = e.estimate_us(k, &|| 77.0);
                }
            }
            // the invariant: every measured key answers from Measured,
            // without touching the lower tiers or the prior closure
            for &mk in &measured_keys {
                assert_eq!(e.tier_of(mk), Tier::Measured);
                let before = e.stats();
                let v = e.estimate_us(mk, &|| panic!("prior hit for measured key"));
                let after = e.stats();
                assert_eq!(v, e.measured_value(mk).unwrap());
                assert_eq!(after.tuned_hits, before.tuned_hits);
                assert_eq!(after.prior_hits, before.prior_hits);
                assert_eq!(after.measured_hits, before.measured_hits + 1);
            }
        }
        assert!(
            !measured_keys.is_empty() && measured_keys.len() >= 6,
            "the walk exercised several variants ({})",
            measured_keys.len()
        );
    }

    /// Warm-started and cold estimators converge to bit-identical
    /// estimates after the same observations: the Tuned tier only fills
    /// the gap before measurement, it never biases the learned value.
    #[test]
    fn warm_and_cold_converge_to_identical_estimates() {
        let mut cold = TieredEstimator::new(0.3);
        let mut warm = TieredEstimator::new(0.3);
        let ka = key(0, 0, 8);
        let kb = key(1, 1, 4);
        warm.warm(ka, 750.0);
        warm.warm(kb, 333.0);

        // before any observation they disagree (that is the point of the
        // warm start: realistic pricing at t=0)
        let prior = || 9999.0;
        assert_eq!(cold.estimate_us(ka, &prior), 9999.0);
        assert_eq!(warm.estimate_us(ka, &prior), 750.0);

        let obs = [
            (ka, 600.0),
            (kb, 200.0),
            (ka, 640.0),
            (ka, 610.0),
            (kb, 260.0),
            (ka, 655.0),
        ];
        for &(k, us) in &obs {
            cold.observe(k, us, prior());
            warm.observe(k, us, prior());
            let c = cold.estimate_us(k, &prior);
            let w = warm.estimate_us(k, &prior);
            assert_eq!(
                c.to_bits(),
                w.to_bits(),
                "measured estimates must be bit-identical"
            );
        }
        assert_eq!(cold.tier_of(ka), Tier::Measured);
        assert_eq!(warm.tier_of(ka), Tier::Measured);
    }

    #[test]
    fn generation_moves_only_on_tier_changes() {
        let mut e = TieredEstimator::new(0.3);
        let k = key(0, 5, 8);
        let g0 = e.generation();

        e.warm(k, 100.0); // unmeasured + new value: bump
        let g1 = e.generation();
        assert_eq!(g1, g0 + 1);

        e.warm(k, 100.0); // same value: no bump
        assert_eq!(e.generation(), g1);

        e.observe(k, 90.0, 50.0); // first measurement: bump
        let g2 = e.generation();
        assert_eq!(g2, g1 + 1);

        e.observe(k, 95.0, 50.0); // EWMA update on measured variant: no bump
        assert_eq!(e.generation(), g2);

        e.warm(k, 42.0); // tuned write under a measured variant: invisible
        assert_eq!(e.generation(), g2);
    }

    #[test]
    fn refinement_promotes_hottest_without_changing_answers() {
        let mut e = TieredEstimator::new(0.3);
        e.set_refine(0, 0); // drive refinement manually
        let hot = key(0, 0, 8);
        let cool = key(0, 1, 8);
        for _ in 0..10 {
            e.observe(hot, 500.0, 100.0);
        }
        e.observe(cool, 900.0, 100.0);

        let before_hot = e.estimate_us(hot, &|| 0.0);
        let g = e.generation();
        let changed = e.refine_hottest(1);
        assert_eq!(changed, 1);
        assert_eq!(e.tuned_value(hot), Some(500.0), "hottest promoted");
        assert_eq!(e.tuned_value(cool), None, "cool variant not promoted");
        assert_eq!(e.estimate_us(hot, &|| 0.0), before_hot, "answer unchanged");
        assert_eq!(e.generation(), g, "refinement is generation-invisible");

        // export shadows Tuned with Measured for the same key
        let exp = e.export();
        assert_eq!(exp.len(), 2);
        assert!(exp
            .iter()
            .all(|&(_, _, t)| t == Tier::Measured), "both keys measured");
    }

    #[test]
    fn refine_cadence_adapts_to_error_and_tier_mix() {
        // fresh estimator: no samples, base cadence
        let fresh = TieredEstimator::new(1.0);
        assert_eq!(fresh.effective_refine_period(), REFINE_PERIOD);

        // every observation misses its prediction by 10ms: err p99 blows
        // the threshold, cadence quarters
        let mut hot = TieredEstimator::new(1.0);
        for g in 0..20 {
            hot.observe(key(0, g, 4), 10_000.0, 0.0);
        }
        assert_eq!(hot.effective_refine_period(), REFINE_PERIOD / 4);
        // a looser threshold relaxes it back to base
        hot.set_refine_err_threshold_us(1e9);
        assert_eq!(hot.effective_refine_period(), REFINE_PERIOD);

        // accurate + measured-dominated: cadence backs off 4x
        let mut calm = TieredEstimator::new(1.0);
        let k = key(0, 0, 4);
        for _ in 0..20 {
            calm.observe(k, 500.0, 500.0); // predicted == observed, err 0
        }
        for _ in 0..100 {
            let _ = calm.estimate_us(k, &|| 0.0);
        }
        assert_eq!(calm.effective_refine_period(), REFINE_PERIOD * 4);

        // period 0 stays disabled regardless of fidelity
        let mut off = calm.clone();
        off.set_refine(0, 0);
        assert_eq!(off.effective_refine_period(), 0);
    }

    #[test]
    fn observation_error_scored_against_the_answering_tier() {
        let mut e = TieredEstimator::new(1.0); // alpha 1: EWMA = last obs
        let k = key(0, 0, 4);
        e.observe(k, 130.0, 100.0); // prior predicted 100 → err 30
        e.observe(k, 130.0, 100.0); // measured predicted 130 → err 0
        let s = e.stats();
        assert_eq!(s.est_err.count(), 2);
        // LatencyHist is log-bucketed (~4% error); mean of {30, 0} ≈ 15
        assert!((s.est_err.mean_us() - 15.0).abs() < 2.0, "{}", s.est_err.mean_us());
    }
}
