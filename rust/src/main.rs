//! `vliwd` — the OoO VLIW JIT serving daemon / toolbox.
//!
//! Subcommands:
//!
//! * `info`          — artifact + device inventory
//! * `golden`        — end-to-end numeric self-check of every artifact
//! * `serve`         — threaded multi-tenant serving demo on real artifacts
//!                     (`--devices v100,t4` turns on the placed launch stage;
//!                     `--frontend off` reverts to the synchronous gate;
//!                     `--listen ADDR` binds the network intake instead of
//!                     replaying a local trace — `--intake-shards N` sizes
//!                     the socket worker pool, `--serve-secs` bounds the run)
//! * `loadgen`       — wire client: replays a generated workload trace over
//!                     TCP against a `serve --listen` endpoint (configurable
//!                     connection count and client-side batch size) and
//!                     prints client-observed p50/p99 + attainment
//! * `bench`         — simulator-backend serving benchmark over a device
//!                     topology, machine-readable JSON out with per-device
//!                     utilization + rebalance counts (the CI smoke);
//!                     `--frontend` runs the wall-clock async-admission
//!                     comparison instead (BENCH_4.json); `--engine-matrix`
//!                     runs one trace through three cells of the unified
//!                     engine's Clock × LaunchStage matrix (BENCH_5.json);
//!                     `--warm-start` runs the same trace cold and
//!                     warm-started from a freshly written
//!                     `artifacts/tuned.json` (BENCH_6.json);
//!                     `--workload slo-mix` replays the class-skewed
//!                     SLO-class trace and emits per-class attainment +
//!                     weighted-share fairness error (BENCH_7.json);
//!                     `--wire` starts a loopback wire server and drives it
//!                     with the load generator — mixed and slo-mix traces,
//!                     client batches of 1 and 8 — and emits client-observed
//!                     latency + intake metrics (BENCH_8.json);
//!                     `--verify` replays the same trace with the issue-time
//!                     plan verifier off and on and emits the overhead ratio
//!                     + violation count (BENCH_9.json);
//!                     `--sched` microbenches the incremental decide against
//!                     the from-scratch naive oracle at held window depths
//!                     64/256/1024 and replays the trace on the incremental
//!                     path (BENCH_10.json);
//!                     `--launch-log out.jsonl` captures the replay's
//!                     admission/launch/completion events for `vliwd audit`
//! * `audit`         — offline launch-log auditor: replays a `--launch-log`
//!                     JSONL capture against the global scheduling
//!                     invariants (AUDIT001..AUDIT005); exit 1 on violation
//! * `lint`          — architecture linter: token-level scan of the source
//!                     tree for layering/clock/panic-hygiene violations
//!                     (LINT001..LINT005); exit 1 on violation
//! * `autotune`      — Table-1 style greedy-vs-collaborative search;
//!                     `--save` persists the tuned estimates as the
//!                     `artifacts/tuned.json` warm-start cache
//! * `cluster`       — Fig-7 style GEMM shape clustering of the model zoo
//!
//! Run `vliwd <cmd> --help` for flags.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use vliw_jit::analysis::{audit, lint};
use vliw_jit::compiler::ir::SloClass;
use vliw_jit::compiler::{autotune, cluster};
use vliw_jit::estimate::{shape_class_label, TunedCache, TunedEntry};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::device::DeviceSpec;
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::gpu::timeline::SharingModel;
use vliw_jit::model::zoo;
use vliw_jit::placement::{DeviceTopology, RebalanceConfig};
use vliw_jit::runtime::executor::ModelExec;
use vliw_jit::runtime::{Manifest, PjrtExecutor};
use vliw_jit::serve::intake::{loadgen::run_loadgen, serve_wire};
use vliw_jit::serve::{
    BatchPolicy, ModelBackend, ServeMetrics, ServeReport, Server, SimBackend,
};
use vliw_jit::util::cli::Args;
use vliw_jit::util::json::Json;
use vliw_jit::util::logging;
use vliw_jit::util::stats::LatencyHist;
use vliw_jit::workload::trace::{
    mixed_tenants, slo_mix_tenants, ArrivalKind, TenantSpec, Trace,
};
use vliw_jit::workload::wire::trace_to_wire;

fn main() -> Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_else(|| "help".into());
    // shift argv so per-command Args::parse sees only the flags
    match cmd.as_str() {
        "info" => info(),
        "golden" => golden(),
        "serve" => serve(),
        "loadgen" => cmd_loadgen(),
        "bench" => cmd_bench(),
        "autotune" => cmd_autotune(),
        "cluster" => cmd_cluster(),
        "audit" => cmd_audit(),
        "lint" => cmd_lint(),
        "help" | "--help" | "-h" => {
            println!(
                "vliwd — OoO VLIW JIT for accelerator inference\n\n\
                 USAGE: vliwd <info|golden|serve|loadgen|bench|autotune|cluster|audit|lint> [flags]\n\
                 Run `vliwd <cmd> --help` for per-command flags."
            );
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `vliwd help`)"),
    }
}

fn parse(mut args: Args) -> Result<vliw_jit::util::cli::Parsed> {
    let argv: Vec<String> = std::env::args().skip(2).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", args.help());
        std::process::exit(0);
    }
    let _ = &mut args;
    args.parse_from(argv).map_err(|e| anyhow::anyhow!("{e}"))
}

fn info() -> Result<()> {
    let m = Manifest::load_default().context("load manifest")?;
    println!("artifacts: {}", m.dir.display());
    let mut names: Vec<&String> = m.models.keys().collect();
    names.sort();
    for name in names {
        let e = &m.models[name];
        println!(
            "  model {name}: {} params, {} MFLOP/query, batches {:?}",
            e.params,
            e.flops_per_query / 1_000_000,
            e.artifacts.iter().map(|a| a.batch).collect::<Vec<_>>()
        );
    }
    for (class, mm, kk, nn, maxp) in m.super_classes() {
        println!("  super {class}: {mm}x{kk}x{nn}, up to {maxp} problems");
    }
    for d in ["v100", "t4", "k80", "tpuv2", "cpu"] {
        let spec = DeviceSpec::by_name(d).expect("known");
        println!(
            "  device {:<8} {:>3} SMs  {:>5.1} TFLOPS  {:>4.0} GB/s  op:byte {:>5.1}",
            spec.name,
            spec.sms,
            spec.peak_flops / 1e12,
            spec.mem_bw / 1e9,
            spec.op_byte_ratio()
        );
    }
    Ok(())
}

fn golden() -> Result<()> {
    let mut ex = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    let mut failures = 0;
    let mut models: Vec<(String, Vec<u32>)> = ex
        .manifest()
        .models
        .values()
        .map(|e| (e.name.clone(), e.artifacts.iter().map(|a| a.batch).collect()))
        .collect();
    models.sort();
    for (model, batches) in models {
        for b in batches {
            match ex.golden_check_model(&model, b) {
                Ok(err) => println!("  OK  {model} b{b}  (max rel err {err:.2e})"),
                Err(e) => {
                    failures += 1;
                    println!("  FAIL {model} b{b}: {e}");
                }
            }
        }
    }
    let supers = ex.manifest().supers.clone();
    for s in supers {
        match ex.golden_check_super(&s) {
            Ok(err) => println!(
                "  OK  super_{}_p{}  (max rel err {err:.2e})",
                s.class, s.problems
            ),
            Err(e) => {
                failures += 1;
                println!("  FAIL super_{}_p{}: {e}", s.class, s.problems);
            }
        }
    }
    if failures > 0 {
        bail!("{failures} golden check(s) failed");
    }
    println!("all goldens passed");
    Ok(())
}

fn serve() -> Result<()> {
    let mut args = Args::new("vliwd serve", "threaded multi-tenant serving demo");
    args.flag("tenants", "6", "number of tenants")
        .flag("rate", "120", "per-tenant request rate (req/s)")
        .flag("requests", "40", "requests per tenant")
        .flag("speedup", "1", "trace time compression factor")
        .flag("seed", "42", "trace seed")
        .flag(
            "workers",
            "1",
            "launch-stage workers (>1: one backend per worker, models execute concurrently)",
        )
        .flag(
            "devices",
            "",
            "device specs for the placed launch stage (e.g. v100,t4); overrides --workers and enables rebalancing",
        )
        .flag(
            "frontend",
            "on",
            "async admission frontend stage: on (default; tenant decisions never wait on the scheduler loop) or off (synchronous gate between channel drains)",
        )
        .flag(
            "listen",
            "",
            "bind the network intake at this address (e.g. 127.0.0.1:7411) and serve wire clients instead of replaying a local trace; --tenants/--rate still declare the served models and SLOs",
        )
        .flag("intake-shards", "2", "socket intake worker pool size (with --listen)")
        .flag("serve-secs", "10", "how long to serve before draining (with --listen)")
        .flag(
            "launch-log",
            "",
            "write admission/launch/completion events as JSONL to this path for offline `vliwd audit`",
        )
        .flag("log", "info", "log level")
        .switch("no-batching", "serve batch-1 FIFO (baseline)");
    let p = parse(args)?;
    logging::set_level_str(p.get("log"));
    let n = p.get_u64("tenants").map_err(|e| anyhow::anyhow!("{e}"))? as u32;
    let rate = p.get_f64("rate").map_err(|e| anyhow::anyhow!("{e}"))?;
    let per = p.get_usize("requests").map_err(|e| anyhow::anyhow!("{e}"))?;
    let speedup = p.get_f64("speedup").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = p.get_u64("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let workers = p.get_usize("workers").map_err(|e| anyhow::anyhow!("{e}"))?;
    // unset = legacy pool; set = must name at least one valid device
    // (same parsing as `vliwd bench`, so `--devices v100,` cannot fail
    // with a confusing "unknown device ''")
    let devices = if p.get("devices").trim().is_empty() {
        Vec::new()
    } else {
        p.get_nonempty_list("devices")
            .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    let launch_log = match p.get("launch-log") {
        "" => None,
        path => Some(Arc::new(
            audit::AuditLog::create(path)
                .map_err(|e| anyhow::anyhow!("create {path}: {e}"))?,
        )),
    };

    let models = ["mlp_small", "gemmnet6", "mlp_large"];
    let listen = p.get("listen").to_string();
    if !listen.is_empty() {
        // wire mode: the executor is built ON the engine thread (inside
        // the serve_wire factory), so nothing heavy happens here
        let shards = p.get_usize("intake-shards").map_err(|e| anyhow::anyhow!("{e}"))?;
        let secs = p.get_f64("serve-secs").map_err(|e| anyhow::anyhow!("{e}"))?;
        let frontend = match p.get("frontend") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --frontend '{other}' (valid: on, off)"),
        };
        let no_batching = p.get_bool("no-batching");
        let tenants = mixed_tenants(n, &models, rate);
        let engine_log = launch_log.clone();
        let ws = serve_wire(
            move || {
                let mut ex = PjrtExecutor::from_default_artifacts().expect("artifacts");
                for m in models {
                    let _ = ex.warmup_model(m);
                }
                let mut s = Server::new(
                    ex,
                    if no_batching {
                        BatchPolicy::NoBatching
                    } else {
                        BatchPolicy::coalescing()
                    },
                );
                s.frontend = frontend;
                s.launch_log = engine_log;
                let tuned_path = std::path::Path::new("artifacts/tuned.json");
                if tuned_path.exists() {
                    s.tuned = TunedCache::load(tuned_path).ok();
                }
                s
            },
            tenants,
            &listen,
            shards,
            launch_log,
        )
        .map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
        println!(
            "listening on {} ({} intake shard(s)); serving for {secs}s",
            ws.addr(),
            shards
        );
        std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        let report = ws.shutdown();
        println!("{}", report.render());
        return Ok(());
    }
    let mut ex = PjrtExecutor::from_default_artifacts().context("artifacts")?;
    for m in models {
        let us = ex.warmup_model(m).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("warmed {m} in {:.1} ms", us / 1e3);
    }
    let policy = if p.get_bool("no-batching") {
        BatchPolicy::NoBatching
    } else {
        BatchPolicy::coalescing()
    };
    let tenants = mixed_tenants(n, &models, rate);
    let trace = Trace::generate(&tenants, per, seed);
    println!(
        "serving {} requests from {n} tenants (offered {:.0} req/s, speedup {speedup}x, {workers} worker(s))...",
        trace.requests.len(),
        trace.offered_load()
    );
    let mut server = Server::new(ex, policy);
    server.launch_log = launch_log;
    match p.get("frontend") {
        "on" => server.frontend = true,
        "off" => server.frontend = false,
        other => bail!("unknown --frontend '{other}' (valid: on, off)"),
    }
    // warm-start the estimator's Tuned tier from the persistent artifact
    // cache, if a previous run (or `vliwd autotune --save`) left one
    let tuned_path = std::path::Path::new("artifacts/tuned.json");
    if tuned_path.exists() {
        match TunedCache::load(tuned_path) {
            Ok(c) => {
                println!("warm-start: {} tuned estimates from {}", c.len(), tuned_path.display());
                server.tuned = Some(c);
            }
            Err(e) => println!("ignoring unreadable {}: {e}", tuned_path.display()),
        }
    }
    let report = if !devices.is_empty() {
        // placed launch stage: one worker per device spec, routed through
        // the placement table with rebalancing enabled
        let topo = DeviceTopology::from_names(&devices).map_err(|e| anyhow::anyhow!("{e}"))?;
        server.run_realtime_placed(
            &trace,
            speedup,
            topo,
            Some(RebalanceConfig::default()),
            move |i, spec| {
                let mut ex = PjrtExecutor::from_default_artifacts()
                    .expect("worker artifacts");
                for m in models {
                    let _ = ex.warmup_model(m);
                }
                let name = spec.name;
                logging::emit(
                    logging::Level::Info,
                    format_args!("launch worker {i} ({name}) ready"),
                );
                ex
            },
        )
    } else if workers > 1 {
        // concurrent launch stage: each worker builds + warms its own
        // executor on its own thread; models execute in parallel
        server.run_realtime_pooled(&trace, speedup, workers, move |i| {
            let mut ex = PjrtExecutor::from_default_artifacts()
                .expect("worker artifacts");
            for m in models {
                let _ = ex.warmup_model(m);
            }
            logging::emit(
                logging::Level::Info,
                format_args!("launch worker {i} ready"),
            );
            ex
        })
    } else {
        server.run_realtime(&trace, speedup)
    };
    println!("{}", report.render());
    // persist what this run learned (measured values shadow stale warm
    // entries) so the next start prices accurately from t = 0
    report
        .tuned
        .save(tuned_path)
        .map_err(|e| anyhow::anyhow!("save {}: {e}", tuned_path.display()))?;
    println!("saved {} tuned estimates to {}", report.tuned.len(), tuned_path.display());
    Ok(())
}

fn cmd_loadgen() -> Result<()> {
    let mut args = Args::new(
        "vliwd loadgen",
        "wire client: replay a generated workload trace against a serve --listen endpoint",
    );
    args.flag("addr", "127.0.0.1:7411", "server address")
        .flag("tenants", "6", "number of tenants")
        .flag("rate", "120", "per-tenant request rate (req/s)")
        .flag("requests", "40", "requests per tenant")
        .flag("seed", "42", "trace seed")
        .flag("batch", "1", "client-side batch size (ops per wire request)")
        .flag("conns", "4", "TCP connections (tenants pin to conns, preserving stream order)")
        .flag("speedup", "1", "trace time compression factor")
        .flag(
            "models",
            "mlp_small,gemmnet6,mlp_large",
            "model names the tenants cycle over (must match the server's)",
        );
    let p = parse(args)?;
    let n = p.get_u64("tenants").map_err(|e| anyhow::anyhow!("{e}"))? as u32;
    let rate = p.get_f64("rate").map_err(|e| anyhow::anyhow!("{e}"))?;
    let per = p.get_usize("requests").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = p.get_u64("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let batch = p.get_usize("batch").map_err(|e| anyhow::anyhow!("{e}"))?;
    let conns = p.get_usize("conns").map_err(|e| anyhow::anyhow!("{e}"))?;
    let speedup = p.get_f64("speedup").map_err(|e| anyhow::anyhow!("{e}"))?;
    let models = p
        .get_nonempty_list("models")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    let addr = std::net::ToSocketAddrs::to_socket_addrs(p.get("addr"))
        .with_context(|| format!("resolve {}", p.get("addr")))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("{} resolves to nothing", p.get("addr")))?;

    let tenants = mixed_tenants(n, &model_refs, rate);
    let trace = Trace::generate(&tenants, per, seed);
    let reqs = trace_to_wire(&trace, batch, speedup);
    println!(
        "replaying {} requests as {} wire request(s) (client batch {batch}) over {conns} conn(s) to {addr}...",
        trace.requests.len(),
        reqs.len()
    );
    let rep = run_loadgen(addr, &reqs, conns).with_context(|| format!("loadgen vs {addr}"))?;
    println!(
        "sent {} batches / {} ops; {} replies ({} ok, {} rejected, {} failed, {} conn timeout(s))",
        rep.sent_batches, rep.sent_ops, rep.replies, rep.ok_ops, rep.rejected_ops,
        rep.failed_ops, rep.timeouts
    );
    println!(
        "client-observed batch latency p50 {:.0} us  p99 {:.0} us  max {:.0} us",
        rep.latency.quantile_us(0.5),
        rep.latency.quantile_us(0.99),
        rep.latency.max_us()
    );
    println!("client-side SLO attainment {:.1}%", rep.attainment() * 100.0);
    Ok(())
}

/// The serving-report core every bench JSON carries (tenant latencies
/// merged for the p99): requests, attainment, throughput_rps, p99_us,
/// mean_pack, launches. One emitter behind BENCH_2..BENCH_5 so the CI
/// asserts that parse these files cannot be broken by one bench drifting.
fn report_core_json(m: &ServeMetrics, o: &mut std::collections::BTreeMap<String, Json>) {
    let mut merged = LatencyHist::new();
    for t in m.tenants.values() {
        merged.merge(&t.latency);
    }
    o.insert("requests".to_string(), Json::Num(m.total_completed() as f64));
    o.insert("throughput_rps".to_string(), Json::Num(m.throughput()));
    o.insert("attainment".to_string(), Json::Num(m.overall_attainment()));
    o.insert("p99_us".to_string(), Json::Num(merged.quantile_us(0.99)));
    o.insert("mean_pack".to_string(), Json::Num(m.jit.mean_pack()));
    o.insert("launches".to_string(), Json::Num(m.jit.launches as f64));
}

/// Skewed two-model tenant set for the placement bench: 3 of 4 tenants
/// hammer the `hot` model at full rate, the rest trickle onto `cold` —
/// the per-device load imbalance the rebalancer exists to fix.
fn skewed_tenants(n: u32, rate: f64) -> Vec<TenantSpec> {
    let slos = [25_000u64, 100_000, 500_000];
    (0..n)
        .map(|i| {
            let hot = i % 4 != 3;
            TenantSpec::new(
                i,
                if hot { "hot" } else { "cold" },
                slos[i as usize % slos.len()],
                if hot { rate } else { rate / 4.0 },
                ArrivalKind::Poisson,
            )
        })
        .collect()
}

fn cmd_bench() -> Result<()> {
    let mut args = Args::new(
        "vliwd bench",
        "simulator-backend placed serving benchmark with machine-readable JSON output",
    );
    args.flag("tenants", "6", "number of tenants")
        .flag("rate", "300", "per-tenant request rate (req/s)")
        .flag("requests", "200", "requests per tenant")
        .flag("seed", "42", "trace seed")
        .flag("devices", "v100", "device topology (comma-separated specs)")
        .flag(
            "workload",
            "skewed",
            "trace shape: 'skewed' (two-model hot/cold, exercises placement), 'mixed' (bursty multi-SLO single model, the stream-prefix coalescing trajectory) or 'slo-mix' (tenants cycling Critical/Standard/BestEffort with 4x load on the batch tier; emits per-class attainment + fairness as BENCH_7.json)",
        )
        .flag(
            "out",
            "",
            "output JSON path (default BENCH_3.json, or BENCH_4.json with --frontend)",
        )
        .flag("speedup", "1", "trace time compression for the --frontend wall-clock runs")
        .switch(
            "frontend",
            "wall-clock async-admission comparison: the same trace through the synchronous gate and the frontend stage, emitted as BENCH_4.json",
        )
        .switch(
            "engine-matrix",
            "run the trace through three cells of the unified engine's Clock x LaunchStage matrix — (virtual x inline), (virtual x placed), (wall x pooled + frontend) — and emit BENCH_5.json",
        )
        .switch(
            "warm-start",
            "run the same trace cold and warm-started from a freshly written artifacts/tuned.json, on a backend with a deliberately biased analytic prior, and emit BENCH_6.json (attainments + estimator tier hit rates + estimate-error quantiles)",
        )
        .switch(
            "wire",
            "serve over a loopback TCP wire and drive it with the load generator — mixed and slo-mix traces, client batches of 1 and 8 — and emit BENCH_8.json (client-observed p50/p99, server attainment, mean pack, intake decode p99)",
        )
        .switch(
            "verify",
            "replay the trace twice — issue-time plan verifier off, then on — and emit BENCH_9.json (throughput ratio, plan checks, violation count)",
        )
        .switch(
            "sched",
            "scheduler microbench: incremental decide vs the from-scratch naive oracle at held window depths 64/256/1024, plus the BENCH_2-floor replay on the incremental path — emits BENCH_10.json (decides/s, decide p50/p99 ns, verifier violations, bucket reuse counters)",
        )
        .flag(
            "launch-log",
            "",
            "write the replay's admission/launch/completion events as JSONL to this path for offline `vliwd audit` (default deterministic replay step only)",
        )
        .switch("static", "pin the initial placement (disable rebalancing)");
    let p = parse(args)?;
    let n = p.get_u64("tenants").map_err(|e| anyhow::anyhow!("{e}"))? as u32;
    let rate = p.get_f64("rate").map_err(|e| anyhow::anyhow!("{e}"))?;
    let per = p.get_usize("requests").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = p.get_u64("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let frontend = p.get_bool("frontend");
    let engine_matrix = p.get_bool("engine-matrix");
    let warm_start = p.get_bool("warm-start");
    let wire = p.get_bool("wire");
    let verify = p.get_bool("verify");
    let sched = p.get_bool("sched");
    let slo_mix = p.get("workload") == "slo-mix";
    if (frontend as u8)
        + (engine_matrix as u8)
        + (warm_start as u8)
        + (wire as u8)
        + (verify as u8)
        + (sched as u8)
        > 1
    {
        bail!("--frontend, --engine-matrix, --warm-start, --wire, --verify and --sched are separate bench steps; pick one");
    }
    if slo_mix && (frontend || engine_matrix || warm_start || wire || verify || sched) {
        bail!("--workload slo-mix is its own bench step (BENCH_7); drop the other step flag");
    }
    let launch_log_path = p.get("launch-log").to_string();
    if !launch_log_path.is_empty()
        && (frontend || engine_matrix || warm_start || wire || verify || sched || slo_mix)
    {
        bail!("--launch-log applies to the default deterministic replay step only");
    }
    let out = match p.get("out") {
        "" if frontend => "BENCH_4.json".to_string(),
        "" if engine_matrix => "BENCH_5.json".to_string(),
        "" if warm_start => "BENCH_6.json".to_string(),
        "" if slo_mix => "BENCH_7.json".to_string(),
        "" if wire => "BENCH_8.json".to_string(),
        "" if verify => "BENCH_9.json".to_string(),
        "" if sched => "BENCH_10.json".to_string(),
        "" => "BENCH_3.json".to_string(),
        o => o.to_string(),
    };
    if wire {
        // the wire bench generates its own mixed + slo-mix traces (both
        // workloads, client batches 1 and 8) — --workload does not apply
        let speedup = p.get_f64("speedup").map_err(|e| anyhow::anyhow!("{e}"))?;
        return bench_wire(n, rate, per, seed, speedup, &out);
    }
    let devices = p
        .get_nonempty_list("devices")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let topo = DeviceTopology::from_names(&devices).map_err(|e| anyhow::anyhow!("{e}"))?;
    let rebalance = if p.get_bool("static") {
        None
    } else {
        Some(RebalanceConfig::default())
    };

    // replayed deterministically on the simulator backend through the
    // placement-aware multi-device drive mode — runs anywhere, no PJRT
    // artifacts needed
    let tenants = match p.get("workload") {
        "skewed" => skewed_tenants(n, rate),
        // one bursty tenant per four: the PR-2 stream-prefix coalescing
        // signal (same_stream_rows / mean_pack trajectory)
        "mixed" => mixed_tenants(n, &["simnet"], rate),
        // the SLO-class priority surface: classes cycle per tenant, the
        // best-effort tier offers 4x the latency tiers' per-tenant rate
        "slo-mix" => slo_mix_tenants(n, &["simnet"], rate),
        other => bail!("unknown --workload '{other}' (valid: skewed, mixed, slo-mix)"),
    };
    let trace = Trace::generate(&tenants, per, seed);
    if sched {
        return bench_sched(&trace, &out);
    }
    if verify {
        return bench_verify(&trace, &out);
    }
    if slo_mix {
        return bench_slo_mix(&trace, &out);
    }
    if warm_start {
        return bench_warm_start(&trace, &out);
    }
    if engine_matrix {
        let speedup = p.get_f64("speedup").map_err(|e| anyhow::anyhow!("{e}"))?;
        return bench_engine_matrix(&trace, &topo, rebalance, speedup, &out);
    }
    if frontend {
        // the admission comparison runs the inline realtime driver — a
        // placed topology does not apply, so reject a NON-DEFAULT
        // topology request instead of silently ignoring it (an explicit
        // `--devices v100` is indistinguishable from the default here and
        // is tolerated: it names the flag's default)
        if p.get("devices") != "v100" || p.get_bool("static") {
            bail!(
                "--frontend benches the inline wall-clock drivers; \
                 a non-default --devices/--static does not apply"
            );
        }
        let speedup = p.get_f64("speedup").map_err(|e| anyhow::anyhow!("{e}"))?;
        return bench_frontend(&trace, speedup, &out);
    }
    let mut server = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    if !launch_log_path.is_empty() {
        server.launch_log = Some(Arc::new(
            audit::AuditLog::create(&launch_log_path)
                .map_err(|e| anyhow::anyhow!("create {launch_log_path}: {e}"))?,
        ));
    }
    let wall = std::time::Instant::now();
    let (report, table) = server.replay_placed(&trace, &topo, rebalance);
    let wall_ms = wall.elapsed().as_secs_f64() * 1e3;
    println!("{}", report.render());
    // a replicated hot group shows up as max replicas > 1
    let max_replicas = table
        .groups()
        .map(|g| table.replicas_of(g).len())
        .max()
        .unwrap_or(0);
    println!("placement: max replicas per group = {max_replicas}");

    let m = &report.metrics;
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("serve_sim".to_string()));
    o.insert("policy".to_string(), Json::Str(report.policy.to_string()));
    report_core_json(m, &mut o);
    o.insert(
        "pack_efficiency".to_string(),
        Json::Num(m.jit.pack_efficiency()),
    );
    o.insert(
        "same_stream_rows".to_string(),
        Json::Num(m.same_stream_rows as f64),
    );
    o.insert("evictions".to_string(), Json::Num(m.jit.evictions as f64));
    let devices_json: Vec<Json> = m
        .devices
        .iter()
        .enumerate()
        .map(|(w, d)| {
            let mut od = std::collections::BTreeMap::new();
            od.insert("worker".to_string(), Json::Num(w as f64));
            od.insert("name".to_string(), Json::Str(d.name.clone()));
            od.insert("launches".to_string(), Json::Num(d.launches as f64));
            od.insert("busy_us".to_string(), Json::Num(d.busy_us));
            od.insert(
                "utilization".to_string(),
                Json::Num(d.utilization(m.span_us)),
            );
            Json::Obj(od)
        })
        .collect();
    o.insert("devices".to_string(), Json::Arr(devices_json));
    o.insert("replications".to_string(), Json::Num(m.replications as f64));
    o.insert("migrations".to_string(), Json::Num(m.migrations as f64));
    o.insert("max_replicas".to_string(), Json::Num(max_replicas as f64));
    o.insert("wall_ms".to_string(), Json::Num(wall_ms));
    std::fs::write(&out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The `bench --frontend` step (BENCH_4): the same trace through both
/// wall-clock admission gates — synchronous (decisions between the
/// scheduler's channel drains) and the async frontend stage. The
/// simulator backend returns instantly (service times are simulated), so
/// the run is paced by arrivals only and both gates should hold
/// attainment; the step's acceptance is that the frontend's attainment is
/// no worse than the synchronous baseline while its admission-decision
/// latency stays decoupled from the scheduler loop.
fn bench_frontend(trace: &Trace, speedup: f64, out: &str) -> Result<()> {
    let run = |frontend: bool| {
        let mut s = Server::new(SimBackend::default(), BatchPolicy::coalescing());
        s.frontend = frontend;
        s.run_realtime(trace, speedup)
    };
    let sync_report = run(false);
    let fe_report = run(true);
    println!("--- synchronous gate ---\n{}", sync_report.render());
    println!("--- admission frontend ---\n{}", fe_report.render());

    let m = &fe_report.metrics;
    let sm = &sync_report.metrics;
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("serve_frontend".to_string()));
    o.insert("policy".to_string(), Json::Str(fe_report.policy.to_string()));
    report_core_json(m, &mut o);
    o.insert(
        "admission_p99_us".to_string(),
        Json::Num(m.admission_latency.quantile_us(0.99)),
    );
    o.insert(
        "frontend_wait_p99_us".to_string(),
        Json::Num(m.frontend_wait.quantile_us(0.99)),
    );
    o.insert(
        "admission_decisions".to_string(),
        Json::Num(m.admission_decisions as f64),
    );
    o.insert(
        "stale_decisions".to_string(),
        Json::Num(m.stale_decisions as f64),
    );
    o.insert(
        "sync_attainment".to_string(),
        Json::Num(sm.overall_attainment()),
    );
    o.insert(
        "sync_admission_p99_us".to_string(),
        Json::Num(sm.admission_latency.quantile_us(0.99)),
    );
    o.insert(
        "sync_throughput_rps".to_string(),
        Json::Num(sm.throughput()),
    );
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The `bench --verify` step (BENCH_9): the same deterministic trace
/// replayed with the issue-time plan verifier off, then on. The verifier
/// is a pure function over the window and each coalesced plan, so the
/// on-run must complete the identical schedule with zero violations; the
/// only thing it may cost is CPU time per issue. Each configuration runs
/// three times and reports its best wall-clock throughput (virtual-time
/// replay rps says nothing about verifier overhead), and CI asserts
/// violations == 0, plan_checks > 0, the on/off ratio ≥ 0.95, and the
/// BENCH_2 attainment floor.
fn bench_verify(trace: &Trace, out: &str) -> Result<()> {
    const REPS: usize = 3;
    let run = |verify: bool| {
        let mut best_secs = f64::INFINITY;
        let mut last = None;
        for _ in 0..REPS {
            let mut s = Server::new(SimBackend::default(), BatchPolicy::coalescing());
            s.verify_plans = Some(verify);
            let wall = std::time::Instant::now();
            let report = s.replay(trace);
            best_secs = best_secs.min(wall.elapsed().as_secs_f64());
            last = Some(report);
        }
        (last.expect("REPS > 0"), best_secs)
    };
    let (off, off_secs) = run(false);
    let (on, on_secs) = run(true);
    println!("--- verifier off ---\n{}", off.render());
    println!("--- verifier on ---\n{}", on.render());
    println!(
        "verifier overhead: {:.1} ms -> {:.1} ms best-of-{REPS} ({} checks, {} violations)",
        off_secs * 1e3,
        on_secs * 1e3,
        on.metrics.jit.plan_checks,
        on.metrics.jit.plan_violations
    );

    let m = &on.metrics;
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("plan_verify".to_string()));
    o.insert("policy".to_string(), Json::Str(on.policy.to_string()));
    report_core_json(m, &mut o);
    o.insert("plan_checks".to_string(), Json::Num(m.jit.plan_checks as f64));
    o.insert(
        "violations".to_string(),
        Json::Num(m.jit.plan_violations as f64),
    );
    o.insert(
        "verify_off_rps".to_string(),
        Json::Num(off.metrics.total_completed() as f64 / off_secs.max(1e-9)),
    );
    o.insert(
        "verify_on_rps".to_string(),
        Json::Num(m.total_completed() as f64 / on_secs.max(1e-9)),
    );
    o.insert(
        "off_attainment".to_string(),
        Json::Num(off.metrics.overall_attainment()),
    );
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// One held-depth cycle of the scheduler microbench: a steady window of
/// `depth` ready ops spread over 16 `(shape, class)` buckets, driven for
/// `iters` decide cycles. Every Launch is drained (issue + complete) and
/// the window refilled into one randomly chosen bucket, so the depth
/// holds while only one or two buckets dirty per cycle — the shape the
/// incremental path is built for. Only the decide call itself is timed;
/// the verifier re-check on incremental launches runs off the clock.
/// Returns `(decides/sec, p50 ns, p99 ns, verifier violations)`.
fn sched_depth_run(depth: usize, iters: usize, incremental: bool) -> (f64, f64, f64, u64) {
    use vliw_jit::analysis::plan::verify_pack;
    use vliw_jit::compiler::coalescer::Coalescer;
    use vliw_jit::compiler::ir::{DispatchRequest, StreamId, TensorOp};
    use vliw_jit::compiler::scheduler::{Decision, Policy, Scheduler};
    use vliw_jit::compiler::window::Window;
    use vliw_jit::estimate::prior::analytic_us;
    use vliw_jit::gpu::kernel::LaunchConfig;
    use vliw_jit::util::rng::Rng;

    let cm = CostModel::v100();
    let est =
        |k: &KernelDesc, _ops: &[&TensorOp]| analytic_us(&cm, &LaunchConfig::greedy(), k);
    // 16 buckets: 8 power-of-two GEMM heights x 2 latency classes, all
    // with multi-second slack so packs launch when full and the
    // best-effort yield rule never enters the picture
    let combos: Vec<(u32, SloClass)> = [1u32, 2, 4, 8, 16, 32, 64, 128]
        .into_iter()
        .flat_map(|m| [(m, SloClass::Critical), (m, SloClass::Standard)])
        .collect();
    let mut rng = Rng::new(0xB10 + depth as u64);
    let mut now = 0.0f64;
    let mut w = Window::new(depth * 2);
    let submit_one = |w: &mut Window, rng: &mut Rng, now: f64, ci: usize| {
        let (m, class) = combos[ci % combos.len()];
        let req = DispatchRequest::new(
            StreamId(rng.below(32) as u32),
            KernelDesc::gemm(m, 256, 256),
            rng.range(1.0e6, 2.0e6),
        )
        .with_class(class)
        .with_independent(true);
        w.submit(req, now).expect("bench window has headroom");
    };
    for i in 0..depth {
        submit_one(&mut w, &mut rng, now, i);
    }

    let mut sched = Scheduler::new(Policy::default(), Coalescer::default());
    let mut hist = LatencyHist::new();
    let mut busy = std::time::Duration::ZERO;
    let mut violations = 0u64;
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        let d = if incremental {
            sched.decide(&mut w, now, 0, est)
        } else {
            sched.decide_naive(&w, now, est)
        };
        let dt = t0.elapsed();
        busy += dt;
        hist.record_us(dt.as_nanos() as f64);
        match d {
            Decision::Launch(p) => {
                if incremental {
                    violations += verify_pack(&w, &sched.coalescer, &p, &[]).len() as u64;
                }
                w.issue(&p.ops);
                for id in &p.ops {
                    w.complete(*id);
                }
                let ci = rng.below(combos.len() as u64) as usize;
                for _ in 0..p.ops.len() {
                    submit_one(&mut w, &mut rng, now, ci);
                }
            }
            Decision::Wait { until_us } => now = until_us.max(now + 1.0),
            Decision::Idle => now += 100.0,
        }
    }
    let rps = iters as f64 / busy.as_secs_f64().max(1e-9);
    (rps, hist.quantile_us(0.5), hist.quantile_us(0.99), violations)
}

/// The `bench --sched` step (BENCH_10): the incremental-decide
/// microbench plus the BENCH_2-floor replay. Each held window depth runs
/// the same deterministic refill loop twice — once through the
/// incremental `decide` (the production path) and once through the
/// from-scratch `decide_naive` oracle — and only the decide calls are
/// timed. CI asserts zero verifier violations across every incremental
/// launch, incremental >= naive throughput at depth 64, >= 3x at depth
/// 1024, and that the replay (scheduled by the incremental path) holds
/// the BENCH_2 attainment floor.
fn bench_sched(trace: &Trace, out: &str) -> Result<()> {
    const DEPTHS: [usize; 3] = [64, 256, 1024];
    const ITERS: usize = 2000;
    let mut o = std::collections::BTreeMap::new();
    o.insert(
        "bench".to_string(),
        Json::Str("sched_incremental".to_string()),
    );
    let mut violations = 0u64;
    for depth in DEPTHS {
        let (inc_rps, inc_p50, inc_p99, v) = sched_depth_run(depth, ITERS, true);
        violations += v;
        let (naive_rps, naive_p50, naive_p99, _) = sched_depth_run(depth, ITERS, false);
        println!(
            "depth {depth:>4}: inc {inc_rps:>9.0}/s p99 {inc_p99:>7.0} ns | \
             naive {naive_rps:>9.0}/s p99 {naive_p99:>7.0} ns | {:.1}x",
            inc_rps / naive_rps.max(1e-9)
        );
        o.insert(format!("sched_inc_rps_d{depth}"), Json::Num(inc_rps));
        o.insert(format!("sched_naive_rps_d{depth}"), Json::Num(naive_rps));
        o.insert(format!("sched_inc_p50_ns_d{depth}"), Json::Num(inc_p50));
        o.insert(format!("sched_inc_p99_ns_d{depth}"), Json::Num(inc_p99));
        o.insert(format!("sched_naive_p50_ns_d{depth}"), Json::Num(naive_p50));
        o.insert(format!("sched_naive_p99_ns_d{depth}"), Json::Num(naive_p99));
    }
    o.insert("verify_violations".to_string(), Json::Num(violations as f64));

    // the floor replay: the same deterministic trace shape as the
    // BENCH_2 step, scheduled end to end by the incremental path
    let mut s = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let report = s.replay(trace);
    println!("{}", report.render());
    let m = &report.metrics;
    report_core_json(m, &mut o);
    o.insert(
        "decides".to_string(),
        Json::Num(m.jit.decide_ns.count() as f64),
    );
    o.insert(
        "decide_p50_ns".to_string(),
        Json::Num(m.jit.decide_ns.quantile_us(0.5)),
    );
    o.insert(
        "decide_p99_ns".to_string(),
        Json::Num(m.jit.decide_ns.quantile_us(0.99)),
    );
    o.insert(
        "buckets_reused".to_string(),
        Json::Num(m.jit.buckets_reused as f64),
    );
    o.insert(
        "buckets_repacked".to_string(),
        Json::Num(m.jit.buckets_repacked as f64),
    );
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The `bench --wire` step (BENCH_8): a loopback wire server (simulator
/// backend, frontend admission on, 2 intake shards) driven by the load
/// generator — the mixed and slo-mix traces, each with client batches of
/// 1 and 8 over 4 connections. The batched client proves the tentpole
/// claim end to end: intake decomposes each 8-op wire request into
/// independent engine requests, the JIT re-coalesces them into packs
/// (CI asserts batched mean_pack stays high), and the client still gets
/// exactly one reply per request. Client-observed latency comes from the
/// generator; attainment, pack shape, and intake decode time from the
/// server report.
fn bench_wire(n: u32, rate: f64, per: usize, seed: u64, speedup: f64, out: &str) -> Result<()> {
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("serve_wire".to_string()));
    let mut decode = LatencyHist::new();
    let workloads = [
        ("mixed", mixed_tenants(n, &["simnet"], rate)),
        ("slomix", slo_mix_tenants(n, &["simnet"], rate)),
    ];
    for (wl, tenants) in workloads {
        for batch in [1usize, 8] {
            let trace = Trace::generate(&tenants, per, seed);
            let reqs = trace_to_wire(&trace, batch, speedup);
            let ws = serve_wire(
                || {
                    let mut s =
                        Server::new(SimBackend::default(), BatchPolicy::coalescing());
                    s.frontend = true;
                    s
                },
                tenants.clone(),
                "127.0.0.1:0",
                2,
                None,
            )
            .map_err(|e| anyhow::anyhow!("bind loopback: {e}"))?;
            let client = run_loadgen(ws.addr(), &reqs, 4)
                .map_err(|e| anyhow::anyhow!("loadgen: {e}"))?;
            let report = ws.shutdown();
            println!("--- {wl} b{batch} ---\n{}", report.render());
            let m = &report.metrics;
            let pfx = format!("{wl}_b{batch}");
            o.insert(
                format!("{pfx}_client_p50_us"),
                Json::Num(client.latency.quantile_us(0.5)),
            );
            o.insert(
                format!("{pfx}_client_p99_us"),
                Json::Num(client.latency.quantile_us(0.99)),
            );
            o.insert(format!("{pfx}_attainment"), Json::Num(client.attainment()));
            o.insert(
                format!("{pfx}_server_attainment"),
                Json::Num(m.overall_attainment()),
            );
            o.insert(format!("{pfx}_mean_pack"), Json::Num(m.jit.mean_pack()));
            o.insert(format!("{pfx}_launches"), Json::Num(m.jit.launches as f64));
            o.insert(format!("{pfx}_sent_ops"), Json::Num(client.sent_ops as f64));
            o.insert(format!("{pfx}_replies"), Json::Num(client.replies as f64));
            decode.merge(&m.intake.decode);
        }
    }
    o.insert(
        "intake_decode_p99_us".to_string(),
        Json::Num(decode.quantile_us(0.99)),
    );
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The `bench --workload slo-mix` step (BENCH_7): the class-skewed trace
/// (tenants cycling Critical/Standard/BestEffort, the batch tier offering
/// 4× the latency tiers' per-tenant rate) replayed deterministically on
/// the simulator backend, decomposed per SLO class. CI asserts the fields
/// parse, critical attainment holds the BENCH_2 floor, and the
/// best-effort tier still makes progress (bounded starvation).
fn bench_slo_mix(trace: &Trace, out: &str) -> Result<()> {
    let mut server = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let report = server.replay(trace);
    println!("{}", report.render());

    let m = &report.metrics;
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("serve_slo_mix".to_string()));
    o.insert("policy".to_string(), Json::Str(report.policy.to_string()));
    report_core_json(m, &mut o);
    for class in SloClass::ALL {
        let c = m.class_metrics(class);
        let name = class.name();
        o.insert(
            format!("{name}_attainment"),
            Json::Num(m.class_attainment(class)),
        );
        o.insert(
            format!("{name}_throughput_rps"),
            Json::Num(m.class_throughput(class)),
        );
        o.insert(format!("{name}_completed"), Json::Num(c.completed() as f64));
        o.insert(format!("{name}_dropped"), Json::Num(c.dropped as f64));
        o.insert(
            format!("{name}_p99_us"),
            Json::Num(c.latency.quantile_us(0.99)),
        );
    }
    o.insert(
        "fairness_error".to_string(),
        Json::Num(fairness_error(trace, m)),
    );
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// Weighted-share fairness error: within each SLO class, the
/// total-variation distance between its tenants' *completed* shares and
/// their *offered* shares (each tenant's weight is its offered load);
/// the reported error is the worst class's. 0 means service inside every
/// class divides exactly in proportion to offered load — no tenant can
/// capture more than its weighted share of its class's service.
fn fairness_error(trace: &Trace, m: &ServeMetrics) -> f64 {
    let mut worst = 0.0f64;
    for class in SloClass::ALL {
        let tenants: Vec<&TenantSpec> = trace
            .tenants
            .iter()
            .filter(|t| t.class == class)
            .collect();
        if tenants.len() < 2 {
            continue;
        }
        let offered: Vec<f64> = tenants
            .iter()
            .map(|t| trace.of_tenant(t.id).count() as f64)
            .collect();
        let completed: Vec<f64> = tenants
            .iter()
            .map(|t| {
                m.tenants
                    .get(&t.id)
                    .map(|tm| (tm.slo_hits + tm.slo_misses) as f64)
                    .unwrap_or(0.0)
            })
            .collect();
        let osum: f64 = offered.iter().sum();
        let csum: f64 = completed.iter().sum();
        if osum <= 0.0 || csum <= 0.0 {
            continue;
        }
        let tv = offered
            .iter()
            .zip(&completed)
            .map(|(of, c)| (of / osum - c / csum).abs())
            .sum::<f64>()
            / 2.0;
        worst = worst.max(tv);
    }
    worst
}

/// Simulator backend whose *analytic prior* over-prices every launch by a
/// constant factor while execution stays truthful — exactly the situation
/// the estimator's Tuned tier exists for. A cold server mis-prices
/// admission and hold decisions until the Measured tier learns each
/// variant; a warm-started one answers from the artifact cache at t = 0.
struct BiasedSim {
    inner: SimBackend,
    bias: f64,
}

impl ModelBackend for BiasedSim {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> vliw_jit::Result<ModelExec> {
        self.inner.execute(model, rows)
    }

    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        self.inner.estimate_us(model, n) * self.bias
    }

    fn max_batch(&self, model: &str) -> u32 {
        self.inner.max_batch(model)
    }

    fn d_in(&self, model: &str) -> usize {
        self.inner.d_in(model)
    }

    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        self.inner.padded_batch(model, n)
    }
}

/// The `bench --warm-start` step (BENCH_6): the same trace replayed twice
/// on a backend whose analytic prior over-prices launches 3× — once cold
/// (the estimator must learn every variant from observations) and once
/// warm-started from the `artifacts/tuned.json` the cold run just saved.
/// Both replays are deterministic virtual-time runs, so the warm run's
/// only advantage is accurate pricing from t = 0: its attainment must be
/// no worse than cold, and its Tuned-tier hit count must be nonzero
/// (every pre-observation query of a warmed variant) — both asserted in
/// CI.
fn bench_warm_start(trace: &Trace, out: &str) -> Result<()> {
    let backend = || BiasedSim {
        inner: SimBackend::default(),
        bias: 3.0,
    };
    // cold: every variant prices off the biased prior until measured
    let mut cold_server = Server::new(backend(), BatchPolicy::coalescing());
    let cold = cold_server.replay(trace);
    println!("--- cold (biased prior) ---\n{}", cold.render());
    // persist what the cold run learned, exactly as `vliwd serve` does
    let path = std::path::Path::new("artifacts/tuned.json");
    cold.tuned
        .save(path)
        .map_err(|e| anyhow::anyhow!("save {}: {e}", path.display()))?;
    let cache = TunedCache::load(path)
        .map_err(|e| anyhow::anyhow!("load {}: {e}", path.display()))?;
    println!("wrote {} ({} entries)", path.display(), cache.len());
    // warm: identical replay, Tuned tier answering before any observation
    let mut warm_server = Server::new(backend(), BatchPolicy::coalescing());
    warm_server.tuned = Some(cache);
    let warm = warm_server.replay(trace);
    println!("--- warm-started ---\n{}", warm.render());

    let (cm, wm) = (&cold.metrics, &warm.metrics);
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("warm_start".to_string()));
    o.insert("policy".to_string(), Json::Str(warm.policy.to_string()));
    report_core_json(wm, &mut o);
    o.insert("cold_attainment".to_string(), Json::Num(cm.overall_attainment()));
    o.insert("warm_attainment".to_string(), Json::Num(wm.overall_attainment()));
    o.insert("tuned_entries".to_string(), Json::Num(cold.tuned.len() as f64));
    for (tag, m) in [("cold", cm), ("warm", wm)] {
        let e = &m.estimator;
        o.insert(format!("{tag}_measured_hits"), Json::Num(e.measured_hits as f64));
        o.insert(format!("{tag}_tuned_hits"), Json::Num(e.tuned_hits as f64));
        o.insert(format!("{tag}_prior_hits"), Json::Num(e.prior_hits as f64));
        o.insert(
            format!("{tag}_est_err_p50_us"),
            Json::Num(e.est_err.quantile_us(0.5)),
        );
        o.insert(
            format!("{tag}_est_err_p99_us"),
            Json::Num(e.est_err.quantile_us(0.99)),
        );
    }
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The `bench --engine-matrix` step (BENCH_5): one trace through three
/// cells of the unified engine's Clock × LaunchStage mode matrix —
/// (virtual × inline), (virtual × placed), (wall × pooled + frontend).
/// Before the engine refactor these were three hand-written loops; now
/// each cell is a thin constructor over the same pipeline, so CI asserts
/// that no cell's attainment falls behind the earlier BENCH_2/3/4 steps.
fn bench_engine_matrix(
    trace: &Trace,
    topo: &DeviceTopology,
    rebalance: Option<RebalanceConfig>,
    speedup: f64,
    out: &str,
) -> Result<()> {
    // virtual × inline: the single-worker timeline cell (Server::replay)
    let mut s1 = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let r1 = s1.replay(trace);
    // virtual × placed: fleet device timelines (+ rebalance unless --static)
    let mut s2 = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    let (r2, _) = s2.replay_placed(trace, topo, rebalance);
    // wall × pooled + frontend: concurrent launch stage, async admission
    let mut s3 = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    s3.frontend = true;
    let r3 = s3.run_realtime_pooled(trace, speedup, 2, |_| SimBackend::default());

    let cells: [(&str, &ServeReport); 3] = [
        ("virtual_inline", &r1),
        ("virtual_placed", &r2),
        ("wall_pooled_frontend", &r3),
    ];
    let mut o = std::collections::BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("engine_matrix".to_string()));
    let mut arr = Vec::new();
    for (name, r) in cells {
        println!("--- {name} ---\n{}", r.render());
        let m = &r.metrics;
        let mut c = std::collections::BTreeMap::new();
        c.insert("cell".to_string(), Json::Str(name.to_string()));
        report_core_json(m, &mut c);
        c.insert(
            "admission_decisions".to_string(),
            Json::Num(m.admission_decisions as f64),
        );
        arr.push(Json::Obj(c));
        // flat per-cell attainment keys for simple CI asserts
        o.insert(format!("{name}_attainment"), Json::Num(m.overall_attainment()));
    }
    o.insert("cells".to_string(), Json::Arr(arr));
    std::fs::write(out, Json::Obj(o).to_string_compact())
        .with_context(|| format!("write {out}"))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_autotune() -> Result<()> {
    let mut args = Args::new("vliwd autotune", "Table-1 greedy vs collaborative search");
    args.flag("tenants", "6", "co-tenancy level")
        .flag("m", "3136", "GEMM rows")
        .flag("k", "576", "GEMM depth")
        .flag("n", "64", "GEMM cols")
        .flag("device", "v100", "device model")
        .switch(
            "save",
            "persist the collaborative-tuned per-batch duration estimates to artifacts/tuned.json (the serving estimator's Tuned-tier warm-start cache)",
        );
    let p = parse(args)?;
    // parse (not by_name): a typo'd device errors with the valid list
    // instead of silently falling back
    let dev = DeviceSpec::parse(p.get("device")).map_err(|e| anyhow::anyhow!("{e}"))?;
    let cm = CostModel::new(dev);
    let k = KernelDesc::gemm(
        p.get_u64("m").unwrap() as u32,
        p.get_u64("k").unwrap() as u32,
        p.get_u64("n").unwrap() as u32,
    );
    let res = autotune::autotune(
        &cm,
        &k,
        p.get_u64("tenants").unwrap() as u32,
        &SharingModel::default(),
    );
    println!(
        "greedy:        cfg {:?}  isolated {:.2} TFLOPS  multiplexed {:.2} TFLOPS",
        (res.greedy.config.tm, res.greedy.config.tn, res.greedy.config.tk),
        res.greedy.isolated_tflops,
        res.greedy.multiplexed_tflops
    );
    println!(
        "collaborative: cfg {:?}  isolated {:.2} TFLOPS  multiplexed {:.2} TFLOPS",
        (
            res.collaborative.config.tm,
            res.collaborative.config.tn,
            res.collaborative.config.tk
        ),
        res.collaborative.isolated_tflops,
        res.collaborative.multiplexed_tflops
    );
    println!(
        "multiplexed speedup {:.2}x, isolated degradation {:.0}%  (paper: 1.25x / ~20%)",
        res.multiplexed_speedup(),
        res.isolated_degradation() * 100.0
    );
    if p.get_bool("save") {
        // per-batch durations under the collaborative config, persisted in
        // the serving estimator's artifact-cache format: entries for a
        // model named after the tuned GEMM, one per power-of-two batch
        // (the padded variants serving actually launches)
        let path = std::path::Path::new("artifacts/tuned.json");
        let mut cache = if path.exists() {
            TunedCache::load(path).unwrap_or_default()
        } else {
            TunedCache::default()
        };
        let model = format!("gemm_{}x{}x{}", k.m, k.k, k.n);
        let mut batch = 1u32;
        while batch <= 64 {
            let kb = KernelDesc::batched(batch, k.m, k.k, k.n);
            cache.insert(
                &model,
                dev.name,
                batch,
                TunedEntry {
                    class: shape_class_label(&kb),
                    est_us: vliw_jit::estimate::prior::analytic_us(
                        &cm,
                        &res.collaborative.config,
                        &kb,
                    ),
                },
            );
            batch *= 2;
        }
        cache
            .save(path)
            .map_err(|e| anyhow::anyhow!("save {}: {e}", path.display()))?;
        println!("saved {} tuned estimates to {}", cache.len(), path.display());
    }
    Ok(())
}

fn cmd_audit() -> Result<()> {
    let mut args = Args::new(
        "vliwd audit",
        "offline launch-log auditor: replay a --launch-log JSONL capture against the global scheduling invariants",
    );
    args.flag(
        "log",
        "LAUNCH_LOG.jsonl",
        "launch log to audit (positional arg also accepted)",
    );
    // `vliwd audit foo.jsonl` reads as naturally as `--log foo.jsonl`
    let positional = std::env::args().nth(2).filter(|a| !a.starts_with('-'));
    let path = match &positional {
        Some(p) => p.clone(),
        None => parse(args)?.get("log").to_string(),
    };
    let report = audit::audit_path(&path).map_err(|e| anyhow::anyhow!("audit {path}: {e}"))?;
    println!(
        "{path}: {} events ({} admissions, {} launches, {} completions)",
        report.events, report.admissions, report.launches, report.completions
    );
    for v in &report.violations {
        println!("{v}");
    }
    if !report.violations.is_empty() {
        bail!("{} audit violation(s)", report.violations.len());
    }
    println!("audit clean");
    Ok(())
}

fn cmd_lint() -> Result<()> {
    let mut args = Args::new(
        "vliwd lint",
        "architecture linter: token-level scan of the source tree for layering/clock/panic-hygiene violations",
    );
    args.flag(
        "root",
        "rust/src",
        "source tree to scan (positional arg also accepted)",
    );
    let positional = std::env::args().nth(2).filter(|a| !a.starts_with('-'));
    let root = match &positional {
        Some(p) => p.clone(),
        None => parse(args)?.get("root").to_string(),
    };
    let report = lint::lint_tree(&root).map_err(|e| anyhow::anyhow!("lint {root}: {e}"))?;
    println!("{root}: {} file(s) scanned", report.files);
    for v in &report.violations {
        println!("{v}");
    }
    if !report.violations.is_empty() {
        bail!("{} lint violation(s)", report.violations.len());
    }
    println!("lint clean");
    Ok(())
}

fn cmd_cluster() -> Result<()> {
    let mut args = Args::new("vliwd cluster", "Fig-7 GEMM shape clustering");
    args.flag("k", "6", "clusters").flag("seed", "42", "kmeans seed");
    let p = parse(args)?;
    let kernels: Vec<KernelDesc> = zoo::zoo().iter().flat_map(|m| m.gemms(1)).collect();
    let clusters = cluster::kmeans(
        &kernels,
        p.get_usize("k").unwrap(),
        p.get_u64("seed").unwrap(),
        100,
    );
    println!("{} kernels from {} models:", kernels.len(), zoo::zoo().len());
    for (i, c) in clusters.iter().enumerate() {
        println!(
            "  cluster {i}: {:>3} kernels  class {}x{}x{}  mean padding {:.1}%",
            c.size(),
            c.class.0,
            c.class.1,
            c.class.2,
            c.mean_padding * 100.0
        );
    }
    Ok(())
}
