//! The plan verifier: static hazard checks on one coalesced superkernel
//! against the issue-window state it is about to be issued from.
//!
//! This is the machine-verifier half of the VLIW analogy: the scheduler
//! and coalescer *construct* plans, and — like LLVM's MachineVerifier
//! after each pass — [`verify_pack`] re-derives every bundle-legality
//! rule from first principles and rejects the plan if any fails. It is
//! a pure function over `(&Window, &Coalescer, plan, live plans)`; the
//! JIT calls it at issue time behind
//! [`Policy::verify_plans`](crate::compiler::scheduler::Policy::verify_plans)
//! (fail-stop under `debug_assertions`, count-and-continue in release).
//!
//! Rules PLAN001–PLAN007 — see the catalog in [`crate::analysis`].

use crate::analysis::Violation;
use crate::compiler::coalescer::{Coalescer, ShapeClass, SuperKernel};
use crate::compiler::ir::{SloClass, TensorOp};
use crate::compiler::window::{OpState, Window};

fn subject(op: &TensorOp) -> String {
    format!("op {} (stream {} seq {})", op.id.0, op.stream.0, op.seq)
}

/// True when `op` legally belongs to a pack of class `class`: either the
/// op quantizes into the class (the normal power-of-two bucket) or the
/// class IS the op's exact dims (the coalescer's out-of-band bucket for
/// shapes whose padding overhead exceeds `max_padding`).
fn shape_matches(class: &ShapeClass, op: &TensorOp) -> bool {
    ShapeClass::of(&op.kernel) == *class
        || (op.kernel.m, op.kernel.k, op.kernel.n) == (class.m, class.k, class.n)
}

/// Verify one plan against the window it will issue from. `live` is the
/// set of already-issued, not-yet-finished plans (the JIT's pending
/// ticket table) — double-issue is checked against it and against the
/// plan itself. Returns every violation found (empty = plan is legal).
pub fn verify_pack(
    window: &Window,
    coalescer: &Coalescer,
    pack: &SuperKernel,
    live: &[&SuperKernel],
) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut group: Option<(u64, String)> = None;
    let mut class: Option<(SloClass, String)> = None;

    for (idx, &id) in pack.ops.iter().enumerate() {
        let Some(op) = window.get(id) else {
            out.push(Violation::error(
                "PLAN006",
                format!("op {}", id.0),
                "plan member is not in the window at issue time",
            ));
            continue;
        };
        let subj = subject(op);

        // PLAN006: only the ready prefix may issue.
        let state = window.state(id);
        if state != Some(OpState::Ready) {
            out.push(Violation::error(
                "PLAN006",
                subj.clone(),
                format!("issued while {state:?}, not in the window's ready prefix"),
            ));
        }

        // PLAN001: per-stream program order for dependent ops. With
        // correct window bookkeeping a dependent op with pending
        // predecessors is never Ready, so a PLAN001 hit specifically
        // means the ready-prefix state machine regressed (the PR 2
        // requeue-order bug class).
        if !op.independent {
            let preds = window.pending_predecessors(id);
            if !preds.is_empty() {
                out.push(Violation::error(
                    "PLAN001",
                    subj.clone(),
                    format!(
                        "dependent op issued with {} lower-seq predecessor(s) of its \
                         stream still pending (first: op {})",
                        preds.len(),
                        preds[0].0
                    ),
                ));
            }
        }

        // PLAN002: one placement/pricing group per launch.
        match &group {
            None => group = Some((op.group, subj.clone())),
            Some((g, first)) if *g != op.group => {
                out.push(Violation::error(
                    "PLAN002",
                    subj.clone(),
                    format!(
                        "group {} mixed into a pack of group {g} (first member {first})",
                        op.group
                    ),
                ));
            }
            _ => {}
        }

        // PLAN003: SLO classes never share a launch.
        match &class {
            None => class = Some((op.class, subj.clone())),
            Some((c, first)) if *c != op.class => {
                out.push(Violation::error(
                    "PLAN003",
                    subj.clone(),
                    format!(
                        "class {} mixed into a {} pack (first member {first})",
                        op.class.name(),
                        c.name()
                    ),
                ));
            }
            _ => {}
        }

        // PLAN004: every member fits the pack's shape class.
        if !shape_matches(&pack.class, op) {
            out.push(Violation::error(
                "PLAN004",
                subj.clone(),
                format!(
                    "kernel {}x{}x{} does not belong to pack class {}x{}x{}",
                    op.kernel.m, op.kernel.k, op.kernel.n, pack.class.m, pack.class.k, pack.class.n
                ),
            ));
        }

        // PLAN007: no op rides two live launches (or one launch twice).
        let dup_in_pack = pack.ops[..idx].contains(&id);
        let in_live = live.iter().any(|l| l.ops.contains(&id));
        if dup_in_pack || in_live {
            let detail = if dup_in_pack {
                "op appears twice in one plan"
            } else {
                "op is already a member of a live (issued, unfinished) launch"
            };
            out.push(Violation::error("PLAN007", subj.clone(), detail));
        }
    }

    // PLAN005: the pack never exceeds the cap its group was priced under.
    if let Some((g, _)) = &group {
        let cap = coalescer.cap_of(*g);
        if pack.ops.len() > cap {
            out.push(Violation::error(
                "PLAN005",
                format!("pack of {} ops in group {g}", pack.ops.len()),
                format!("exceeds the group's coalescer cap of {cap}"),
            ));
        }
    }

    out
}

/// Ids of the rules a slice of violations tripped, deduplicated and
/// sorted — the mutation tests assert on exactly this.
pub fn rule_ids(violations: &[Violation]) -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = Vec::new();
    for v in violations {
        if !ids.contains(&v.rule) {
            ids.push(v.rule);
        }
    }
    ids.sort_unstable();
    ids
}

/// Convenience for tests: did exactly this one rule fire (possibly more
/// than once), and nothing else?
pub fn only_rule(violations: &[Violation], rule: &str) -> bool {
    !violations.is_empty() && violations.iter().all(|v| v.rule == rule)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::{DispatchRequest, StreamId};
    use crate::gpu::kernel::KernelDesc;

    fn window_with(reqs: Vec<DispatchRequest>) -> Window {
        let mut w = Window::new(64);
        for r in reqs {
            w.submit(r, 0.0).expect("window has capacity");
        }
        w
    }

    fn req(stream: u32, m: u32, k: u32, n: u32) -> DispatchRequest {
        DispatchRequest::new(StreamId(stream), KernelDesc::gemm(m, k, n), 10_000.0)
    }

    #[test]
    fn clean_coalesced_plans_verify_clean() {
        let w = window_with(vec![req(0, 1, 256, 256), req(1, 1, 256, 256)]);
        let c = Coalescer::default();
        let ready = w.ready();
        let packs = c.pack(&ready);
        assert!(!packs.is_empty());
        for p in &packs {
            let vs = verify_pack(&w, &c, p, &[]);
            assert!(vs.is_empty(), "clean plan flagged: {vs:?}");
        }
    }

    #[test]
    fn out_of_band_exact_singleton_is_legal() {
        // padding overhead of 3x513x5 into its power-of-two class
        // (4x1024x8) is ~0.77 > max_padding, so the coalescer gives the
        // op an exact out-of-band class; PLAN004 must accept that class
        // even though ShapeClass::of disagrees with it.
        let w = window_with(vec![req(0, 3, 513, 5)]);
        let c = Coalescer::default();
        let ready = w.ready();
        let packs = c.pack(&ready);
        assert_eq!(packs.len(), 1);
        assert!(verify_pack(&w, &c, &packs[0], &[]).is_empty());
    }

    #[test]
    fn double_issue_against_live_ticket_is_plan007() {
        let mut w = window_with(vec![req(0, 1, 256, 256), req(1, 1, 256, 256)]);
        let c = Coalescer::default();
        let packs = c.pack(&w.ready());
        assert_eq!(packs.len(), 1);
        let live = packs[0].clone();
        w.issue(&live.ops);
        // replaying the same plan while its ticket is live must trip
        // PLAN007 (and PLAN006: the members are InFlight, not Ready)
        let vs = verify_pack(&w, &c, &live, &[&live]);
        let hit = rule_ids(&vs);
        assert!(hit.contains(&"PLAN007"), "{vs:?}");
        assert!(hit.contains(&"PLAN006"), "{vs:?}");
    }

    #[test]
    fn cap_overflow_is_plan005() {
        let reqs: Vec<_> = (0..4).map(|s| req(s, 1, 256, 256)).collect();
        let w = window_with(reqs);
        let c = Coalescer::new(2, 1.0); // cap 2
        let ready = w.ready();
        // hand-build the oversized pack the real coalescer would split
        let class = ShapeClass::of(&KernelDesc::gemm(1, 256, 256));
        let pack = SuperKernel {
            class,
            ops: ready.iter().map(|o| o.id).collect(),
            useful_flops: 1.0,
            kernel: class.kernel(4),
        };
        let vs = verify_pack(&w, &c, &pack, &[]);
        assert!(only_rule(&vs, "PLAN005"), "{vs:?}");
    }
}
