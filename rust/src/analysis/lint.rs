//! The architecture linter: layering rules as CI-failing diagnostics.
//!
//! Several of the repo's contracts are about *where* code may live, not
//! what it computes — all pricing state in `estimate/`, no wall clock in
//! the virtual-time layers, no panicking lock/socket handling on the
//! intake path, no silent unbounded queues. Until now those were grep
//! discipline; `vliwd lint` walks `rust/src/` with a small token-level
//! scanner (comments, strings, and `#[cfg(test)]` tails are stripped
//! before matching, so prose and test rigs never false-positive) and
//! reports rules LINT001–LINT005 (catalog in [`crate::analysis`]).
//!
//! # Suppression grammar
//!
//! A diagnostic on line *n* is suppressed by `// lint: <RULEID> <why>`
//! on line *n* or *n − 1*. For LINT004 (unbounded channels) and LINT005
//! (`#[allow]`) the justification comment is not an escape hatch but
//! the rule itself: every hit must say why it is sound.

use std::fs;
use std::path::{Path, PathBuf};

use crate::analysis::Violation;
use crate::Result;

/// Layers that run on virtual time and must never read the wall clock.
const PURE_LAYERS: [&str; 6] = [
    "compiler/",
    "estimate/",
    "gpu/",
    "model/",
    "placement/",
    "workload/",
];

/// Call sites whose `Result`/`LockResult` must not be unwrapped on the
/// intake path (LINT003): a poisoned lock or a peer reset must degrade,
/// not kill the shard.
const INTAKE_FALLIBLE: [&str; 7] = [
    "lock(",
    "accept(",
    "connect(",
    "set_nonblocking(",
    "local_addr(",
    "read_frame(",
    "write_frame(",
];

/// What [`lint_tree`] found.
#[derive(Debug, Default)]
pub struct LintReport {
    /// `.rs` files scanned.
    pub files: usize,
    /// Every diagnostic, in path order.
    pub violations: Vec<Violation>,
}

/// Blank out comments, string literals, and char literals, preserving
/// byte positions and newlines so line numbers survive. Lifetimes are
/// kept (a `'` not closing within two bytes is not a char literal).
fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = vec![b' '; b.len()];
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'\n' => {
                out[i] = b'\n';
                i += 1;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            out[i] = b'\n';
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                // raw string? look back over #s for an `r` prefix
                let mut j = i;
                let mut hashes = 0usize;
                while j > 0 && b[j - 1] == b'#' {
                    j -= 1;
                    hashes += 1;
                }
                let raw = j > 0 && b[j - 1] == b'r';
                i += 1;
                if raw {
                    while i < b.len() {
                        if b[i] == b'"'
                            && b.len() - i > hashes
                            && (1..=hashes).all(|h| b[i + h] == b'#')
                        {
                            i += 1 + hashes;
                            break;
                        }
                        if b[i] == b'\n' {
                            out[i] = b'\n';
                        }
                        i += 1;
                    }
                } else {
                    while i < b.len() && b[i] != b'"' {
                        if b[i] == b'\\' {
                            i += 1; // escape marker; the escaped char follows
                        }
                        if i < b.len() {
                            if b[i] == b'\n' {
                                out[i] = b'\n';
                            }
                            i += 1;
                        }
                    }
                    i += 1; // closing quote
                }
            }
            b'\'' => {
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    // escaped char literal: '\n', '\'', '\u{..}'
                    i += 2;
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i += 1;
                } else if i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                    i += 3; // plain char literal 'c'
                } else {
                    out[i] = b'\''; // lifetime
                    i += 1;
                }
            }
            c => {
                out[i] = c;
                i += 1;
            }
        }
    }
    String::from_utf8(out).unwrap_or_default()
}

/// Is the diagnostic on 0-based line `i` justified/suppressed by a
/// `// lint: <rule>` comment on the same or preceding line?
fn justified(orig: &[&str], i: usize, rule: &str) -> bool {
    let hit = |l: &str| l.contains("// lint:") && l.contains(rule);
    hit(orig[i]) || (i > 0 && hit(orig[i - 1]))
}

/// Lint one file's source. `rel` is the path relative to the scan root
/// (e.g. `serve/intake/mod.rs`), used for the layer rules.
pub fn lint_source(rel: &str, source: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let stripped = strip(source);
    let code_lines: Vec<&str> = stripped.lines().collect();
    let orig_lines: Vec<&str> = source.lines().collect();
    let pure_layer = PURE_LAYERS.iter().any(|p| rel.starts_with(p));
    let pricing_ok = rel.starts_with("estimate/") || rel == "util/stats.rs";
    let intake = rel.starts_with("serve/intake/");

    for (i, code) in code_lines.iter().enumerate() {
        // test rigs may do what production code may not
        if code.contains("#[cfg(test)]") {
            break;
        }
        let subject = || format!("{rel}:{}", i + 1);
        if code.contains("Ewma::new") && !pricing_ok && !justified(&orig_lines, i, "LINT001") {
            out.push(Violation::error(
                "LINT001",
                subject(),
                "Ewma pricing state outside estimate/ and util/stats.rs — all \
                 cost-model pricing flows through the tiered estimator",
            ));
        }
        if code.contains("Instant::now") && pure_layer && !justified(&orig_lines, i, "LINT002") {
            out.push(Violation::error(
                "LINT002",
                subject(),
                "wall clock read in a virtual-time layer — real time enters only \
                 via WallClock and the wire",
            ));
        }
        if intake
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && INTAKE_FALLIBLE.iter().any(|p| code.contains(p))
            && !justified(&orig_lines, i, "LINT003")
        {
            out.push(Violation::error(
                "LINT003",
                subject(),
                "unwrap/expect on a lock or socket result on the intake path — \
                 recover (into_inner) or degrade instead of killing the shard",
            ));
        }
        if code.contains("mpsc::channel") && !justified(&orig_lines, i, "LINT004") {
            out.push(Violation::error(
                "LINT004",
                subject(),
                "unbounded channel without a `// lint: LINT004 <why>` \
                 justification — backpressure decisions must be explicit",
            ));
        }
        if code.contains("#[allow") && !justified(&orig_lines, i, "LINT005") {
            out.push(Violation::error(
                "LINT005",
                subject(),
                "#[allow] without a `// lint: LINT005 <why>` justification \
                 naming why the exemption is sound",
            ));
        }
    }
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for e in fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (`vliwd lint [root]`, default
/// `rust/src`).
pub fn lint_tree(root: impl AsRef<Path>) -> Result<LintReport> {
    let root = root.as_ref();
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut report = LintReport::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let source = fs::read_to_string(&path)?;
        report.files += 1;
        report.violations.extend(lint_source(&rel, &source));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_blanks_comments_and_strings() {
        let s = strip("let a = \"Ewma::new\"; // Instant::now\nlet b = 1;");
        assert!(!s.contains("Ewma::new"));
        assert!(!s.contains("Instant::now"));
        assert!(s.contains("let a ="));
        assert!(s.contains("let b = 1;"));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    fn strip_handles_char_literals_and_lifetimes() {
        let s = strip("fn f<'a>(x: &'a str) -> char { '\"' }");
        assert!(s.contains("fn f<'a>(x: &'a str)"));
        assert!(!s.contains('"'));
    }

    #[test]
    fn flags_ewma_outside_estimate() {
        let vs = lint_source("serve/engine.rs", "let e = Ewma::new(0.3);\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT001");
        assert!(lint_source("estimate/measured.rs", "let e = Ewma::new(0.3);\n").is_empty());
        assert!(lint_source("util/stats.rs", "let e = Ewma::new(0.3);\n").is_empty());
    }

    #[test]
    fn flags_instant_in_pure_layer() {
        let vs = lint_source("compiler/jit.rs", "let t = Instant::now();\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT002");
        assert!(lint_source("serve/engine.rs", "let t = Instant::now();\n").is_empty());
    }

    #[test]
    fn flags_lock_unwrap_in_intake() {
        let vs = lint_source("serve/intake/mod.rs", "let g = m.lock().unwrap();\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT003");
        let vs = lint_source("serve/intake/shard.rs", "let g = m.lock().expect(\"x\");\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT003");
        // recovery is the sanctioned idiom
        let ok = "let g = m.lock().unwrap_or_else(|p| p.into_inner());\n";
        assert!(lint_source("serve/intake/mod.rs", ok).is_empty());
        // outside the intake path the rule does not apply
        assert!(lint_source("serve/engine.rs", "let g = m.lock().unwrap();\n").is_empty());
    }

    #[test]
    fn flags_unjustified_unbounded_channel() {
        let vs = lint_source("serve/engine.rs", "let (tx, rx) = mpsc::channel::<u64>();\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT004");
        let ok = "// lint: LINT004 test\nlet (tx, rx) = mpsc::channel::<u64>();\n";
        assert!(lint_source("serve/engine.rs", ok).is_empty());
    }

    #[test]
    fn flags_bare_allow() {
        let vs = lint_source("serve/engine.rs", "#[allow(dead_code)]\nfn f() {}\n");
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "LINT005");
        let justified = "#[allow(dead_code)] // lint: LINT005 scaffolding for PR 10\nfn f() {}\n";
        assert!(lint_source("serve/engine.rs", justified).is_empty());
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { Ewma::new(0.3); }\n}\n";
        assert!(lint_source("serve/engine.rs", src).is_empty());
    }
}
