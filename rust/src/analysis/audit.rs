//! The launch-log auditor: offline replay of a serve/bench run's
//! structured event log against the system's *global* invariants.
//!
//! The plan verifier ([`crate::analysis::plan`]) checks one launch at a
//! time; the invariants that span launches — per-stream launch order
//! across requeues, admission bounds, placement totality across
//! rebalance epochs, wire reply exactness, attainment arithmetic — need
//! the whole timeline. [`AuditLog`] is the writer side: the engine and
//! admission gates emit one JSON object per line
//! (`vliwd serve/bench --launch-log out.jsonl`), cheap enough to leave
//! on in CI smoke runs. [`audit_lines`] is the reader side
//! (`vliwd audit <log>`): a single pass over the log that re-derives
//! rules AUDIT001–AUDIT005 (catalog in [`crate::analysis`]) from the
//! events alone — no access to in-process state, so a regression cannot
//! hide behind the bookkeeping that caused it.
//!
//! # Event schema (one object per line)
//!
//! * `admit` — `stream, group, class, queued, inflight, bound`: a
//!   request passed an admission gate; `queued`/`inflight` are the
//!   group's post-admit window counts, `bound` the per-class cap it was
//!   priced under.
//! * `reject` — `class, reason`: a gate refused a request.
//! * `launch` — `ticket, group, class, cap, ops[{stream, seq,
//!   independent}]`: one superkernel issued to the launch stage.
//! * `complete` — `stream, seq, group, done_us, deadline_us, met,
//!   failed, token`: one op reached a terminal state (`token` 0 =
//!   non-wire request).
//! * `rebalance` — `epoch, replicas[{group, replicas}]`: the placement
//!   rebalancer committed actions; the full table is snapshotted.
//! * `reply` — `token`: the engine routed a terminal outcome for a wire
//!   op to the reply sink.
//! * `purge` — `conn, batches[]`: a disconnect purged a connection's
//!   pending batches from the reply table.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::Mutex;

use crate::analysis::Violation;
use crate::util::json::Json;
use crate::{Error, Result};

/// Thread-safe append-only jsonl writer for launch/admission events.
/// One line per event, flushed per event so a crashed run still leaves
/// an auditable prefix. Shared as `Arc<AuditLog>` by the engine thread,
/// the intake reply table, and the frontend's reject path.
pub struct AuditLog {
    w: Mutex<BufWriter<File>>,
}

impl AuditLog {
    /// Create (truncate) the log file.
    pub fn create(path: impl AsRef<Path>) -> Result<AuditLog> {
        let f = File::create(path)?;
        Ok(AuditLog {
            w: Mutex::new(BufWriter::new(f)),
        })
    }

    fn line(&self, j: Json) {
        let mut w = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let _ = writeln!(w, "{}", j.to_string_compact());
        let _ = w.flush();
    }

    /// A request passed an admission gate.
    pub fn admit(
        &self,
        stream: u32,
        group: u64,
        class: &str,
        queued: usize,
        inflight: usize,
        bound: usize,
    ) {
        self.line(events::admit(stream, group, class, queued, inflight, bound));
    }

    /// A gate refused a request.
    pub fn reject(&self, class: &str, reason: &str) {
        self.line(events::reject(class, reason));
    }

    /// One superkernel issued; `ops` is `(stream, seq, independent)`.
    pub fn launch(
        &self,
        ticket: u64,
        group: u64,
        class: &str,
        cap: usize,
        ops: &[(u32, u64, bool)],
    ) {
        self.line(events::launch(ticket, group, class, cap, ops));
    }

    /// One op reached a terminal state (`token` 0 = non-wire).
    #[allow(clippy::too_many_arguments)] // lint: LINT005 flat event row mirrors the jsonl schema
    pub fn complete(
        &self,
        stream: u32,
        seq: u64,
        group: u64,
        done_us: f64,
        deadline_us: f64,
        met: bool,
        failed: bool,
        token: u64,
    ) {
        self.line(events::complete(stream, seq, group, done_us, deadline_us, met, failed, token));
    }

    /// The rebalancer committed actions; `replicas` is `(group, count)`
    /// for the whole table.
    pub fn rebalance(&self, epoch: u64, replicas: &[(u64, usize)]) {
        self.line(events::rebalance(epoch, replicas));
    }

    /// A wire op's terminal outcome was routed to the reply sink.
    pub fn reply(&self, token: u64) {
        self.line(events::reply(token));
    }

    /// A disconnect purged a connection's pending batches.
    pub fn purge(&self, conn: u64, batches: &[u64]) {
        self.line(events::purge(conn, batches));
    }
}

/// Event constructors, public so the mutation tests can seed synthetic
/// timelines without touching the filesystem.
pub mod events {
    use crate::util::json::{obj, Json};

    fn n(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// `admit` event (see module doc).
    pub fn admit(
        stream: u32,
        group: u64,
        class: &str,
        queued: usize,
        inflight: usize,
        bound: usize,
    ) -> Json {
        obj(vec![
            ("ev", Json::Str("admit".into())),
            ("stream", n(stream as u64)),
            ("group", n(group)),
            ("class", Json::Str(class.into())),
            ("queued", n(queued as u64)),
            ("inflight", n(inflight as u64)),
            ("bound", n(bound as u64)),
        ])
    }

    /// `reject` event.
    pub fn reject(class: &str, reason: &str) -> Json {
        obj(vec![
            ("ev", Json::Str("reject".into())),
            ("class", Json::Str(class.into())),
            ("reason", Json::Str(reason.into())),
        ])
    }

    /// `launch` event; `ops` is `(stream, seq, independent)`.
    pub fn launch(
        ticket: u64,
        group: u64,
        class: &str,
        cap: usize,
        ops: &[(u32, u64, bool)],
    ) -> Json {
        let rows = ops
            .iter()
            .map(|&(stream, seq, independent)| {
                obj(vec![
                    ("stream", n(stream as u64)),
                    ("seq", n(seq)),
                    ("independent", Json::Bool(independent)),
                ])
            })
            .collect();
        obj(vec![
            ("ev", Json::Str("launch".into())),
            ("ticket", n(ticket)),
            ("group", n(group)),
            ("class", Json::Str(class.into())),
            ("cap", n(cap as u64)),
            ("ops", Json::Arr(rows)),
        ])
    }

    /// `complete` event.
    #[allow(clippy::too_many_arguments)] // lint: LINT005 flat event row mirrors the jsonl schema
    pub fn complete(
        stream: u32,
        seq: u64,
        group: u64,
        done_us: f64,
        deadline_us: f64,
        met: bool,
        failed: bool,
        token: u64,
    ) -> Json {
        obj(vec![
            ("ev", Json::Str("complete".into())),
            ("stream", n(stream as u64)),
            ("seq", n(seq)),
            ("group", n(group)),
            ("done_us", Json::Num(done_us)),
            ("deadline_us", Json::Num(deadline_us)),
            ("met", Json::Bool(met)),
            ("failed", Json::Bool(failed)),
            ("token", n(token)),
        ])
    }

    /// `rebalance` event; `replicas` is `(group, count)`.
    pub fn rebalance(epoch: u64, replicas: &[(u64, usize)]) -> Json {
        let rows = replicas
            .iter()
            .map(|&(group, count)| obj(vec![("group", n(group)), ("replicas", n(count as u64))]))
            .collect();
        obj(vec![
            ("ev", Json::Str("rebalance".into())),
            ("epoch", n(epoch)),
            ("replicas", Json::Arr(rows)),
        ])
    }

    /// `reply` event.
    pub fn reply(token: u64) -> Json {
        obj(vec![("ev", Json::Str("reply".into())), ("token", n(token))])
    }

    /// `purge` event.
    pub fn purge(conn: u64, batches: &[u64]) -> Json {
        obj(vec![
            ("ev", Json::Str("purge".into())),
            ("conn", n(conn)),
            ("batches", Json::Arr(batches.iter().map(|&b| n(b)).collect())),
        ])
    }
}

/// What [`audit_lines`] found: event counts plus every violation.
#[derive(Debug, Default)]
pub struct AuditReport {
    /// Events scanned.
    pub events: usize,
    /// `launch` events seen.
    pub launches: u64,
    /// `complete` events seen.
    pub completions: u64,
    /// Admission (`admit` + `reject`) events seen.
    pub admissions: u64,
    /// Every rule breach, in log order.
    pub violations: Vec<Violation>,
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    match j.req(key)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(Error::Json(format!("field '{key}' not a bool"))),
    }
}

/// One stream's current *life*: the window drops a fully-drained
/// stream's bookkeeping and a returning stream restarts at seq 0, so
/// the auditor tracks launches per life and resets on a seq-0 relaunch
/// of a drained stream.
#[derive(Default)]
struct StreamLife {
    /// Seqs launched in this life (a requeued straggler relaunches the
    /// same seq — contiguity, not uniqueness, is the invariant).
    launched: HashSet<u64>,
    /// Launches minus completions; 0 means possibly drained.
    outstanding: i64,
}

/// Audit a launch log already read into memory; one pass, log order.
pub fn audit_lines(text: &str) -> Result<AuditReport> {
    let mut report = AuditReport::default();
    let mut streams: HashMap<u32, StreamLife> = HashMap::new();
    // AUDIT003 baseline: the group set of the first rebalance snapshot.
    let mut placed_groups: Option<BTreeSet<u64>> = None;
    // AUDIT004 bookkeeping.
    let mut replies: HashMap<u64, u64> = HashMap::new();
    let mut completed_tokens: HashSet<u64> = HashSet::new();
    let mut purged_batches: HashSet<u64> = HashSet::new();

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| Error::Json(format!("launch log line {}: {e}", lineno + 1)))?;
        report.events += 1;
        let at = |ev: &str| format!("event {} ({ev})", lineno + 1);
        match j.req_str("ev")?.as_str() {
            "admit" => {
                report.admissions += 1;
                let queued = j.req_u64("queued")?;
                let inflight = j.req_u64("inflight")?;
                let bound = j.req_u64("bound")?;
                if queued + inflight > bound {
                    report.violations.push(Violation::error(
                        "AUDIT002",
                        at("admit"),
                        format!(
                            "group {} class {} admitted to queued {queued} + inflight \
                             {inflight} > bound {bound} it was priced under",
                            j.req_u64("group")?,
                            j.req_str("class")?
                        ),
                    ));
                }
            }
            "reject" => {
                report.admissions += 1;
            }
            "launch" => {
                report.launches += 1;
                let ops = j.req("ops")?.as_arr().ok_or_else(|| {
                    Error::Json(format!("launch log line {}: ops not an array", lineno + 1))
                })?;
                for op in ops {
                    let stream = op.req_u64("stream")? as u32;
                    let seq = op.req_u64("seq")?;
                    let independent = req_bool(op, "independent")?;
                    let life = streams.entry(stream).or_default();
                    if seq == 0 && life.outstanding == 0 && life.launched.contains(&0) {
                        // drained stream restarting at seq 0: new life
                        life.launched.clear();
                    }
                    if !independent {
                        if let Some(missing) = (0..seq).find(|s| !life.launched.contains(s)) {
                            report.violations.push(Violation::error(
                                "AUDIT001",
                                at("launch"),
                                format!(
                                    "dependent op stream {stream} seq {seq} launched before \
                                     seq {missing} of its stream"
                                ),
                            ));
                        }
                    }
                    life.launched.insert(seq);
                    life.outstanding += 1;
                }
            }
            "complete" => {
                report.completions += 1;
                let stream = j.req_u64("stream")? as u32;
                if let Some(life) = streams.get_mut(&stream) {
                    life.outstanding -= 1;
                }
                let done_us = j.req_f64("done_us")?;
                let deadline_us = j.req_f64("deadline_us")?;
                let met = req_bool(&j, "met")?;
                let failed = req_bool(&j, "failed")?;
                let consistent = met == (!failed && done_us <= deadline_us);
                if !consistent {
                    report.violations.push(Violation::error(
                        "AUDIT005",
                        at("complete"),
                        format!(
                            "stream {stream} seq {}: met={met} inconsistent with \
                             failed={failed}, done_us={done_us}, deadline_us={deadline_us}",
                            j.req_u64("seq")?
                        ),
                    ));
                }
                let token = j.req_u64("token")?;
                if token != 0 {
                    completed_tokens.insert(token);
                }
            }
            "rebalance" => {
                let epoch = j.req_u64("epoch")?;
                let rows = j.req("replicas")?.as_arr().ok_or_else(|| {
                    Error::Json(format!("launch log line {}: replicas not an array", lineno + 1))
                })?;
                let mut groups = BTreeSet::new();
                for row in rows {
                    let group = row.req_u64("group")?;
                    let count = row.req_u64("replicas")?;
                    groups.insert(group);
                    if count == 0 {
                        report.violations.push(Violation::error(
                            "AUDIT003",
                            at("rebalance"),
                            format!("group {group} has 0 replicas at rebalance epoch {epoch}"),
                        ));
                    }
                }
                match &placed_groups {
                    None => placed_groups = Some(groups),
                    Some(base) if *base != groups => {
                        report.violations.push(Violation::error(
                            "AUDIT003",
                            at("rebalance"),
                            format!(
                                "group set changed at rebalance epoch {epoch}: \
                                 {base:?} -> {groups:?}"
                            ),
                        ));
                    }
                    Some(_) => {}
                }
            }
            "reply" => {
                let token = j.req_u64("token")?;
                let count = replies.entry(token).or_insert(0);
                *count += 1;
                if *count == 2 {
                    report.violations.push(Violation::error(
                        "AUDIT004",
                        at("reply"),
                        format!("token {token} replied more than once"),
                    ));
                }
            }
            "purge" => {
                let batches = j.req("batches")?.as_arr().ok_or_else(|| {
                    Error::Json(format!("launch log line {}: batches not an array", lineno + 1))
                })?;
                for b in batches {
                    purged_batches.insert(b.as_u64().ok_or_else(|| {
                        Error::Json(format!("launch log line {}: batch not a u64", lineno + 1))
                    })?);
                }
            }
            other => {
                return Err(Error::Json(format!(
                    "launch log line {}: unknown event '{other}'",
                    lineno + 1
                )));
            }
        }
    }

    // AUDIT004 end-state: every completed wire op was replied or purged.
    for &token in &completed_tokens {
        if !replies.contains_key(&token) && !purged_batches.contains(&(token >> 16)) {
            report.violations.push(Violation::error(
                "AUDIT004",
                format!("token {token}"),
                "completed wire op was never replied to and its batch was never purged",
            ));
        }
    }

    Ok(report)
}

/// Audit a launch log on disk (`vliwd audit <log>`).
pub fn audit_path(path: impl AsRef<Path>) -> Result<AuditReport> {
    let text = std::fs::read_to_string(path)?;
    audit_lines(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn text_of(events: Vec<Json>) -> String {
        events
            .iter()
            .map(|e| e.to_string_compact())
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn clean_timeline() -> Vec<Json> {
        vec![
            events::admit(0, 0, "standard", 1, 0, 256),
            events::launch(1, 0, "standard", 8, &[(0, 0, false)]),
            events::complete(0, 0, 0, 900.0, 1_000.0, true, false, 0),
            events::launch(2, 0, "standard", 8, &[(0, 1, false)]),
            events::complete(0, 1, 0, 1_500.0, 1_000.0, false, false, 0),
            events::rebalance(1, &[(0, 1), (1, 2)]),
            events::rebalance(2, &[(0, 2), (1, 1)]),
        ]
    }

    #[test]
    fn clean_log_audits_clean() {
        let r = audit_lines(&text_of(clean_timeline())).unwrap();
        assert_eq!(r.events, 7);
        assert_eq!(r.launches, 2);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn requeue_relaunch_and_drain_restart_are_legal() {
        // seq 1 is evicted and relaunched (same seq twice), then the
        // stream drains fully and a NEW life restarts at seq 0 — both
        // are legitimate timelines AUDIT001 must not flag.
        let events = vec![
            events::launch(1, 0, "standard", 8, &[(7, 0, false)]),
            events::complete(7, 0, 0, 10.0, 100.0, true, false, 0),
            events::launch(2, 0, "standard", 8, &[(7, 1, false)]),
            events::launch(3, 0, "standard", 8, &[(7, 1, false)]),
            events::complete(7, 1, 0, 80.0, 100.0, true, false, 0),
            events::launch(4, 0, "standard", 8, &[(7, 0, false)]),
            events::launch(5, 0, "standard", 8, &[(7, 1, false)]),
        ];
        // outstanding after line 5: 2 launches of seq 1, 1 completion —
        // the relaunch drifts the count, so the life never "drains" and
        // the seq-0 relaunch is judged against the old life's seqs; the
        // contiguity rule still accepts it (weaker, never false-positive)
        let r = audit_lines(&text_of(events)).unwrap();
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn seq_swap_is_audit001() {
        let events = vec![
            events::launch(1, 0, "standard", 8, &[(3, 1, false)]),
            events::launch(2, 0, "standard", 8, &[(3, 0, false)]),
        ];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT001");
    }

    #[test]
    fn independent_out_of_order_is_legal() {
        let events = vec![
            events::launch(1, 0, "standard", 8, &[(3, 1, true)]),
            events::launch(2, 0, "standard", 8, &[(3, 0, false)]),
        ];
        let r = audit_lines(&text_of(events)).unwrap();
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn over_admission_is_audit002() {
        let events = vec![events::admit(0, 2, "best_effort", 100, 29, 128)];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT002");
    }

    #[test]
    fn totality_break_is_audit003() {
        let events = vec![
            events::rebalance(1, &[(0, 1), (1, 1)]),
            events::rebalance(2, &[(0, 0), (1, 2)]),
        ];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT003");
    }

    #[test]
    fn duplicate_reply_is_audit004() {
        let token = (5 << 16) | 1;
        let events = vec![
            events::complete(0, 0, 0, 10.0, 100.0, true, false, token),
            events::reply(token),
            events::reply(token),
        ];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT004");
    }

    #[test]
    fn purged_completion_without_reply_is_legal() {
        let token = (5 << 16) | 1;
        let events = vec![
            events::complete(0, 0, 0, 10.0, 100.0, true, false, token),
            events::purge(3, &[5]),
        ];
        let r = audit_lines(&text_of(events)).unwrap();
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn unreplied_completion_is_audit004() {
        let token = (5 << 16) | 1;
        let events = vec![events::complete(0, 0, 0, 10.0, 100.0, true, false, token)];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT004");
    }

    #[test]
    fn met_mismatch_is_audit005() {
        let events = vec![events::complete(0, 0, 0, 2_000.0, 1_000.0, true, false, 0)];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT005");
    }

    #[test]
    fn failed_op_reported_met_is_audit005() {
        let events = vec![events::complete(0, 0, 0, 500.0, 1_000.0, true, true, 0)];
        let r = audit_lines(&text_of(events)).unwrap();
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "AUDIT005");
    }

    #[test]
    fn garbage_line_is_an_error_not_a_pass() {
        assert!(audit_lines("{not json").is_err());
        assert!(audit_lines("{\"ev\":\"mystery\"}").is_err());
    }
}
