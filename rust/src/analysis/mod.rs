//! The schedule verifier: machine-checked invariants for plans, launch
//! logs, and the source tree.
//!
//! Eight PRs of scheduler growth left the system's load-bearing
//! contracts in prose, asserts, and grep discipline. This module is the
//! LLVM-machine-verifier analogue for the OoO VLIW JIT: three analysis
//! passes that share one [`Violation`] catalog, so every hazard a
//! classical VLIW compiler would reject statically is rejected here too
//! — at issue time ([`plan`]), offline over a launch log ([`audit`]),
//! and over the source tree itself ([`lint`]).
//!
//! # Invariant catalog
//!
//! Every rule id, the layer it guards, the PR that introduced the
//! contract, and the test that pins it. The mutation tests live in
//! `rust/tests/proptest_invariants.rs`; pass-local unit tests live next
//! to each pass.
//!
//! ## Plan rules ([`plan::verify_pack`], issue-time, behind [`Policy::verify_plans`])
//!
//! | rule | invariant | layer | since | pinned by |
//! |------|-----------|-------|-------|-----------|
//! | `PLAN001` | a dependent op never issues while a lower-seq op of its stream is still pending — program order within a stream is a VLIW bundle's "no backwards slot" rule | `compiler/window.rs` | PR 2 (stream-prefix coalescing) | `mutation_plan_catches_requeue_order_bug` |
//! | `PLAN002` | a superkernel never mixes model groups — group is the unit of placement and pricing | `compiler/coalescer.rs` | PR 3 (placement) | `mutation_plan_flags_cross_group_pack` |
//! | `PLAN003` | a superkernel never mixes SLO classes — class-weighted deadlines assume class-pure packs | `compiler/scheduler.rs` | PR 7 (one priority surface) | `mutation_plan_flags_merged_classes` |
//! | `PLAN004` | every member matches the pack's shape class (exact-dims singletons excepted) — padding math is per-class | `compiler/coalescer.rs` | seed + PR 2 | `mutation_plan_flags_shape_mix` |
//! | `PLAN005` | pack size never exceeds the group's coalescer cap it was priced under | `compiler/coalescer.rs` | PR 2 | `mutation_plan_flags_cap_overflow` |
//! | `PLAN006` | ops issue only from the window's ready prefix | `compiler/window.rs` | PR 1 (one JIT core) | `mutation_plan_flags_unready_issue` |
//! | `PLAN007` | no op appears in two live (issued, unfinished) tickets — double-issue corrupts inflight accounting | `compiler/jit.rs` | PR 1 | `mutation_plan_flags_double_issue` |
//!
//! ## Audit rules ([`audit::audit_lines`], offline, `vliwd audit <log>`)
//!
//! | rule | invariant | layer | since | pinned by |
//! |------|-----------|-------|-------|-----------|
//! | `AUDIT001` | per-stream launch order for dependent streams: a dependent op launches only after every lower seq of its stream launched (requeue relaunches and drained-stream seq restarts excepted) | `serve/engine.rs` + `compiler/jit.rs` | PR 2 | `mutation_audit_flags_seq_swap` |
//! | `AUDIT002` | an admitted request's post-admit queued+inflight never exceeds the admission bound it was priced under — stale views may shed extra, never over-admit | `serve/engine.rs` gates + `serve/frontend.rs` | PR 4, per-class PR 7 | `mutation_audit_catches_stale_view_overadmit` |
//! | `AUDIT003` | placement-table totality at every rebalance epoch: every group keeps ≥ 1 replica and the group set never changes | `placement/` | PR 3 | `mutation_audit_flags_totality_break` |
//! | `AUDIT004` | exactly one reply per wire token — duplicates double-complete a client batch slot; completions must be replied or purged | `serve/intake/` | PR 8 | `mutation_audit_flags_duplicate_reply` |
//! | `AUDIT005` | attainment arithmetic: `met ⇔ !failed ∧ done_us ≤ deadline_us` for every completion | `compiler/jit.rs` + `serve/metrics.rs` | PR 2 (histogram fix) | `mutation_audit_flags_met_mismatch` |
//!
//! ## Lint rules ([`lint::lint_tree`], `vliwd lint`, CI-failing)
//!
//! | rule | invariant | layer | since | pinned by |
//! |------|-----------|-------|-------|-----------|
//! | `LINT001` | `Ewma::new` (cost-model pricing state) only under `estimate/` and `util/stats.rs` — ALL pricing flows through the tiered estimator | whole tree | PR 6 (one cost model) | `lint::tests::flags_ewma_outside_estimate` |
//! | `LINT002` | `Instant::now` never in the pure virtual-time layers (`compiler/`, `estimate/`, `gpu/`, `model/`, `placement/`, `workload/`) — wall time enters only via `WallClock` and the wire | whole tree | PR 5 (one engine) | `lint::tests::flags_instant_in_pure_layer` |
//! | `LINT003` | no `unwrap()`/`expect(` on lock or socket results in `serve/intake/` — a poisoned lock or peer reset must not kill an intake shard | `serve/intake/` | PR 8 | `lint::tests::flags_lock_unwrap_in_intake` |
//! | `LINT004` | unbounded `mpsc::channel` only with a `// lint: LINT004 <why>` justification — backpressure decisions are explicit | whole tree | PR 8 | `lint::tests::flags_unjustified_unbounded_channel` |
//! | `LINT005` | `#[allow(...)]` only with a `// lint: LINT005 <why>` justification naming why the exemption is sound | whole tree | PR 9 | `lint::tests::flags_bare_allow` |
//!
//! # Severity
//!
//! Every rule above is [`Severity::Error`]: each one guards an invariant
//! whose violation silently corrupts benchmarks built on top of it.
//! [`Severity::Warning`] exists for future advisory rules so the catalog
//! doesn't need a schema change to grow them.
//!
//! [`Policy::verify_plans`]: crate::compiler::scheduler::Policy::verify_plans

pub mod audit;
pub mod lint;
pub mod plan;

use std::fmt;

/// How bad a violation is. Every current rule is an error (CI-failing);
/// the variant space leaves room for advisory rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Invariant breach: the pass's caller must fail (panic at issue
    /// time under debug, non-zero exit from `vliwd audit`/`lint`).
    Error,
    /// Advisory: reported but never fails a run.
    Warning,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Error => write!(f, "error"),
            Severity::Warning => write!(f, "warning"),
        }
    }
}

/// One rule breach, shared by all three passes: the plan verifier's
/// subject is a launch/op, the auditor's a log event, the linter's a
/// `file:line`.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Stable rule id from the catalog above (`PLAN…`/`AUDIT…`/`LINT…`).
    pub rule: &'static str,
    pub severity: Severity,
    /// What the rule fired on — an op/launch (`stream 3 seq 2`), a log
    /// event (`event 41`), or a source location (`serve/intake/mod.rs:128`).
    pub subject: String,
    /// Human explanation of the breach, with the offending values.
    pub detail: String,
}

impl Violation {
    /// An error-severity violation (every catalog rule today).
    pub fn error(
        rule: &'static str,
        subject: impl Into<String>,
        detail: impl Into<String>,
    ) -> Self {
        Violation {
            rule,
            severity: Severity::Error,
            subject: subject.into(),
            detail: detail.into(),
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} [{}] {}: {}",
            self.severity, self.rule, self.subject, self.detail
        )
    }
}
