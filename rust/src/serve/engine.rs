//! The one serving event loop: a Clock × LaunchStage pipeline.
//!
//! Every drive mode in this repo is the SAME loop — admit → issue →
//! launch → complete → rebalance — parameterized over two small traits
//! instead of five hand-written copies:
//!
//! * a [`Clock`] decides how time advances between events:
//!   [`VirtualClock`] jumps deterministically to the next event (trace
//!   replays, benches); [`WallClock`] wraps `Instant` — real time flows on
//!   its own and the loop paces on bounded channel waits;
//! * a [`LaunchStage`] decides where an issued pack executes:
//!   [`TimelineStage`] models per-worker busy-until device timelines
//!   (virtual time; completions ordered by a `BinaryHeap` keyed on
//!   `(done_us, ticket)`); [`InlineStage`] executes on the driver thread
//!   (wall clock, the single-device realtime mode); [`PoolStage`] routes
//!   to [`StatefulPool`] workers, one backend each (wall clock,
//!   concurrent launches).
//!
//! Placement and the admission frontend are *orthogonal options*, not
//! modes: an optional [`Placement`] (topology + group→replicas table +
//! optional rebalancer) makes any stage route launches to the
//! least-loaded replica of the launch's group, and the wall-clock runs
//! may put admission on a dedicated frontend thread (see
//! [`crate::serve::frontend`]); the virtual runs keep the synchronous
//! gate so replays stay deterministic.
//!
//! # The mode matrix
//!
//! | cell                          | constructor                      | `vliwd` flags                        |
//! |-------------------------------|----------------------------------|--------------------------------------|
//! | virtual × timeline(1)         | [`crate::serve::Server::replay`] | `bench` (BENCH_2 mixed workload)     |
//! | virtual × timeline(fleet)     | [`crate::serve::Server::replay_placed`] | `bench --devices v100,t4 [--static]` |
//! | wall × inline [× frontend]    | [`crate::serve::Server::run_realtime`] | `serve` / `bench --frontend` (`--frontend on|off`) |
//! | wall × pool [× frontend]      | [`crate::serve::Server::run_realtime_pooled`] | `serve --workers N`           |
//! | wall × pool × placed [× fe]   | [`crate::serve::Server::run_realtime_placed`] | `serve --devices v100,t4`     |
//!
//! `vliwd bench --engine-matrix` smokes three cells of this table through
//! one trace and emits `BENCH_5.json` (asserted in CI).
//!
//! Two cells are *defined* rather than special-cased:
//!
//! * **virtual × inline** is realized as a single-worker
//!   [`TimelineStage`]: a virtual clock cannot block on an inline
//!   execution, so "one device executing serially" IS a one-entry
//!   busy-until timeline. This makes `replay` and `replay_placed` on a
//!   single homogeneous v100 *the same computation* (pinned by
//!   `prop_replay_and_replay_placed_agree_on_single_v100`).
//! * **virtual × frontend** stays unreachable on purpose: a wall-clock
//!   frontend thread would race the virtual clock and destroy replay
//!   determinism. Virtual runs price through the same
//!   [`frontend::GroupView`] pricing path synchronously, so the two gates
//!   cannot disagree on identical state.
//!
//! # Threading model (wall clock)
//!
//! A generator thread paces client arrivals into an intake channel. With
//! the frontend on (the default), a dedicated frontend-stage thread owns
//! that channel, the admission gate and the stream-interning table,
//! pricing every request against the [`frontend::AdmissionView`] snapshot
//! this loop publishes once per iteration — accept/reject never waits on
//! an issue/launch/collect iteration. Accepted requests flow here as
//! pre-priced [`FromFrontend::Admitted`] records; the loop owns the JIT
//! window, the clock, the launch stage, the per-worker backlog accounting
//! and the drain counters, and is the only snapshot writer. With the
//! frontend off, the gate runs synchronously between channel drains.
//!
//! The frontend's per-(tenant, model) accept counters and this loop's
//! mirrored drain counters are compacted epoch-wise: a stream idle for a
//! full [`frontend::FRONTEND_EPOCH_US`] whose accepts the scheduler has
//! fully drained is retired on the gate ([`FrontendGate::advance_epoch`])
//! and a [`FromFrontend::Retire`] record tells this loop to drop its
//! mirror — bookkeeping stays bounded by the *live* stream set under
//! tenant churn, not by every pair ever served. Retired pairs that return
//! are interned as fresh stream ids (ids are never reused), which matches
//! the window's own fully-drained-stream-restarts-clean semantics.
//!
//! # Straggler accounting
//!
//! The engine drives the JIT exclusively through
//! [`JitCompiler::issue_ready`] / [`JitCompiler::finish_launch`], so all
//! serving modes share the *asynchronous* eviction contract (measured or
//! modeled time stands; evictions are counted, never re-charged). The
//! synchronous retry-charging contract lives on in the kernel-level
//! [`JitCompiler::run_trace`]/`pump` drive mode — see the module docs in
//! [`crate::compiler::jit`].

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::analysis::audit::AuditLog;
use crate::compiler::ir::{DispatchRequest, OpId, SloClass, StreamId};
use crate::compiler::jit::{JitCompiler, OpCompletion, PackRun, PendingLaunch};
use crate::gpu::kernel::KernelDesc;
use crate::placement::{
    DeviceTopology, Placer, PlacementTable, Rebalancer,
};
use crate::runtime::executor::ModelExec;
use crate::runtime::golden;
use crate::serve::admission::{Admission, Admit};
use crate::serve::frontend::{
    self, AdmissionView, FrontendGate, FrontendReport, GateExtras, GateRequest,
    RejectReason, TenantShaper, ViewCell, FRONTEND_EPOCH_US, STALE_VIEW_US,
};
use crate::serve::metrics::ServeMetrics;
use crate::serve::server::{ModelBackend, ModelSlot, ServeExecutor, ServeReport};
use crate::util::threadpool::{Stage, StatefulPool};
use crate::workload::trace::Trace;

/// The serving JIT instance every stage drives: executor = the serving
/// adapter, payload = the request row.
pub type ServeJit<X> = JitCompiler<ServeExecutor<X>, Vec<f32>>;

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// How time advances between engine events.
pub trait Clock {
    /// True for deterministic virtual time (sync admission gate, event
    /// jumps); false for the wall clock (channel-paced, frontend allowed).
    fn is_virtual(&self) -> bool;
    /// The driver's current time, µs since the run's origin.
    fn now_us(&self) -> f64;
    /// Advance toward `t_us`. Virtual time jumps exactly; wall time is a
    /// no-op (real time flows on its own; pacing happens in the engine's
    /// bounded channel waits).
    fn advance_to(&mut self, t_us: f64);
    /// The wall instant that maps to `now_us() == 0` — the origin every
    /// arrival/completion stamp is measured against. Only meaningful for
    /// wall clocks; virtual clocks have no wall origin.
    fn origin(&self) -> Instant;
}

/// Deterministic virtual time: the engine jumps it to the next event
/// (arrival, device completion, or scheduler wake) — nothing ever waits.
pub struct VirtualClock {
    now_us: f64,
    created: Instant,
}

impl VirtualClock {
    /// A virtual clock at t = 0.
    pub fn new() -> Self {
        VirtualClock {
            now_us: 0.0,
            created: Instant::now(),
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn is_virtual(&self) -> bool {
        true
    }

    fn now_us(&self) -> f64 {
        self.now_us
    }

    fn advance_to(&mut self, t_us: f64) {
        self.now_us = self.now_us.max(t_us);
    }

    fn origin(&self) -> Instant {
        self.created
    }
}

/// Real time: `now_us` is the elapsed wall clock since construction; the
/// engine paces its loop on bounded channel waits instead of jumping.
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    /// A wall clock whose origin is *now*.
    pub fn new() -> Self {
        WallClock { t0: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn is_virtual(&self) -> bool {
        false
    }

    fn now_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }

    fn advance_to(&mut self, _t_us: f64) {}

    fn origin(&self) -> Instant {
        self.t0
    }
}

/// Monotonic nanosecond clock injected into the JIT's decide timer
/// ([`crate::compiler::jit::JitCompiler::decide_clock`]). A plain fn (not a
/// closure) so the pure compiler layer carries no `Instant` of its own —
/// the serve layer owns the anchor, initialized at first call.
fn decide_clock_ns() -> u64 {
    use std::sync::OnceLock;
    static T0: OnceLock<Instant> = OnceLock::new();
    T0.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// Placement (orthogonal engine option)
// ---------------------------------------------------------------------------

/// The launch-routing option: which worker runs a launch, how the gate
/// prices a group's drain parallelism, and (optionally) how the table
/// evolves between observation windows.
pub struct Placement {
    /// The fleet: workers backed by device specs, dedup'd into classes.
    pub topo: DeviceTopology,
    /// group → replicas; launches route to the least-loaded replica.
    pub table: PlacementTable,
    /// Hot-group replication / cold-group migration between windows.
    pub rebal: Option<Rebalancer>,
    /// Register and account per-device metrics (`ServeMetrics::devices`).
    /// Off for the anonymous homogeneous pools (`replay`,
    /// `run_realtime_pooled`), whose real hardware the topology does not
    /// describe — `metrics.devices` staying empty is their documented
    /// contract.
    pub report_devices: bool,
}

/// Seed the placement table: LPT over each group's total estimated work
/// in the trace (batch-1 estimates × request count), priced through the
/// tiered estimator so a warm-started Tuned entry shapes the initial
/// placement too (cold it resolves to the same backend prior as before).
/// Shared by every placed constructor so initial placements cannot
/// diverge.
pub fn seed_placement<B: ModelBackend>(
    exec: &ServeExecutor<B>,
    trace: &Trace,
    index: &BTreeMap<String, u64>,
    groups: u64,
    topo: &DeviceTopology,
) -> PlacementTable {
    // a single worker hosts every group no matter the weights: skip the
    // O(trace) estimate pass (`replay` seeds a 1-v100 table on every call)
    let costs: Vec<(u64, f64)> = if topo.len() <= 1 {
        (0..groups).map(|g| (g, 1.0)).collect()
    } else {
        let mut work: BTreeMap<u64, f64> = (0..groups).map(|g| (g, 0.0)).collect();
        for r in &trace.requests {
            *work.entry(index[&r.model]).or_insert(0.0) +=
                exec.estimate_group_us(index[&r.model], 1);
        }
        work.into_iter().collect()
    };
    Placer::place(&costs, topo)
}

/// Effective drain parallelism of a group's replica set: how many
/// primary-class-equivalents serve it (Σ replica speed ÷ primary-replica
/// speed, so the units match the estimate, which is priced on the primary
/// class). Two equal replicas = 2.0; a v100 primary with a k80 replica =
/// ~1.25 — dividing the drain by the raw replica count would underprice
/// it on mixed fleets and re-admit doomed requests.
pub fn drain_parallelism(table: &PlacementTable, topo: &DeviceTopology, group: u64) -> f64 {
    let reps = table.replicas_of(group);
    match reps.first() {
        None => 1.0,
        Some(p) => {
            let primary = topo.speed_of_worker(*p).max(1e-9);
            (reps.iter().map(|w| topo.speed_of_worker(*w)).sum::<f64>() / primary)
                .max(1.0)
        }
    }
}

/// Pin every group's primary estimation class to its current primary
/// replica's device class (at startup and after each rebalance).
fn repin_group_classes<B: ModelBackend>(
    exec: &mut ServeExecutor<B>,
    table: &PlacementTable,
    topo: &DeviceTopology,
    groups: u64,
) {
    for g in 0..groups {
        if let Some(w) = table.primary_of(g) {
            exec.set_group_class(g, topo.class_of(w));
        }
    }
}

/// Admission gate inputs for a placed group: speed-weighted replica
/// parallelism plus the least-loaded replica's measured backlog (per
/// `backlog_of`, the stage's own signal — booked pool estimates or
/// device-timeline queues). The ONE implementation behind every placed
/// stage, so two stages can never disagree on how a replica set is
/// priced.
fn placed_gate_inputs(
    p: &Placement,
    group: u64,
    backlog_of: impl Fn(usize) -> f64,
) -> (f64, Option<f64>) {
    let b = p
        .table
        .replicas_of(group)
        .iter()
        .map(|w| backlog_of(*w))
        .fold(f64::INFINITY, f64::min);
    (
        drain_parallelism(&p.table, &p.topo, group),
        Some(if b.is_finite() { b } else { 0.0 }),
    )
}

/// Admission gate inputs for a *pool-backed* stage: (drain parallelism,
/// measured booked backlog of the worker the launch would land on).
/// Placed pools price the least-loaded replica's booked backlog; the
/// legacy hash-routed pool prices the hash-routed worker's entry; with no
/// workers nothing is measured and the JIT's in-flight term prices the
/// drain. Kept as a free function so the legacy arm and the launch router
/// cannot drift apart (pinned by `pooled_paths_agree_on_admission_inputs`).
pub(crate) fn pool_gate_inputs(
    placement: Option<&Placement>,
    pool_workers: usize,
    worker_backlog: &[f64],
    group: u64,
) -> (f64, Option<f64>) {
    match placement {
        Some(p) => placed_gate_inputs(p, group, |w| {
            worker_backlog.get(w).copied().unwrap_or(0.0)
        }),
        None if pool_workers > 0 => (
            1.0,
            Some(
                worker_backlog
                    .get(group as usize % pool_workers)
                    .copied()
                    .unwrap_or(0.0),
            ),
        ),
        None => (1.0, None),
    }
}

// ---------------------------------------------------------------------------
// LaunchStage
// ---------------------------------------------------------------------------

/// One finished launch handed back by a stage, ready to fold into the JIT.
pub struct StageDone {
    /// The launch ticket ([`JitCompiler::finish_launch`] handle).
    pub ticket: u64,
    /// Completion stamp on the run's clock, µs.
    pub done_us: f64,
    /// Worker that executed it (0 for inline).
    pub worker: usize,
    /// Coalescing group of the launch (rebalancer observation key).
    pub group: u64,
    /// Execution outcome.
    pub run: PackRun,
}

/// Where issued packs execute. A stage owns the routing decision, the
/// per-worker load signals the gate prices, and the completion events the
/// engine folds back into the JIT.
pub trait LaunchStage<X: ModelBackend> {
    /// Route and begin one issued launch at `now_us`.
    fn launch(
        &mut self,
        jit: &mut ServeJit<X>,
        slots: &[ModelSlot],
        placement: Option<&Placement>,
        group: u64,
        l: PendingLaunch,
        now_us: f64,
    );
    /// Launches finished by `now_us`, in a deterministic order where the
    /// stage is deterministic. `block` permits one bounded wait (wall
    /// drain phase: arrivals are gone, only results remain).
    fn poll(
        &mut self,
        placement: Option<&Placement>,
        now_us: f64,
        block: bool,
    ) -> Vec<StageDone>;
    /// The next completion instant (virtual clocks advance to it).
    fn next_done_us(&self) -> Option<f64> {
        None
    }
    /// (drain parallelism, measured backlog) the admission gate prices
    /// for one more request of `group` under this stage's routing.
    fn gate_inputs(
        &self,
        placement: Option<&Placement>,
        group: u64,
        now_us: f64,
    ) -> (f64, Option<f64>);
}

/// One issued-but-unfinished launch on a device timeline, ordered by
/// (done_us, ticket) so the pop order — hence the whole virtual replay —
/// is deterministic.
struct TimelineEntry {
    done_us: f64,
    ticket: u64,
    worker: usize,
    group: u64,
    run: PackRun,
}

impl PartialEq for TimelineEntry {
    fn eq(&self, other: &Self) -> bool {
        self.done_us.total_cmp(&other.done_us) == std::cmp::Ordering::Equal
            && self.ticket == other.ticket
    }
}

impl Eq for TimelineEntry {}

impl PartialOrd for TimelineEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimelineEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.done_us
            .total_cmp(&other.done_us)
            .then(self.ticket.cmp(&other.ticket))
    }
}

/// Virtual-time device timelines: each worker is a busy-until scalar, a
/// launch queues at `max(free_at, now)` and completes `duration / speed`
/// later. In-flight completions live in a min-heap keyed on `(done_us,
/// ticket)` — popping due entries is O(log n) per launch, replacing the
/// old linear min-scan + `swap_remove` that made deep device queues
/// quadratic to replay.
pub struct TimelineStage {
    free_at: Vec<f64>,
    inflight: BinaryHeap<Reverse<TimelineEntry>>,
}

impl TimelineStage {
    /// Timelines for `workers` devices (≥ 1). A single worker is the
    /// virtual single-device "inline" cell of the mode matrix.
    pub fn new(workers: usize) -> Self {
        TimelineStage {
            free_at: vec![0.0; workers.max(1)],
            inflight: BinaryHeap::new(),
        }
    }
}

impl<X: ModelBackend> LaunchStage<X> for TimelineStage {
    fn launch(
        &mut self,
        jit: &mut ServeJit<X>,
        _slots: &[ModelSlot],
        placement: Option<&Placement>,
        group: u64,
        l: PendingLaunch,
        now_us: f64,
    ) {
        let worker = match placement {
            Some(p) => p.table.route(group, &self.free_at),
            None => 0,
        };
        let (class, speed) = match placement {
            Some(p) => (p.topo.class_of(worker), p.topo.speed_of_worker(worker)),
            None => (0, 1.0),
        };
        // re-price on the routed class: a slow replica running at its own
        // speed is not a straggler
        let est_routed = jit.executor().estimate_group_on_class_us(
            group,
            class,
            l.pack.ops.len() as u32,
        );
        jit.reprice_pending(l.ticket, est_routed);
        let mut run = jit.run_issued(l.ticket);
        run.duration_us /= speed.max(1e-9);
        run.device_class = class;
        let start = self.free_at[worker].max(now_us);
        let done_us = start + run.duration_us;
        self.free_at[worker] = done_us;
        self.inflight.push(Reverse(TimelineEntry {
            done_us,
            ticket: l.ticket,
            worker,
            group,
            run,
        }));
    }

    fn poll(
        &mut self,
        _placement: Option<&Placement>,
        now_us: f64,
        _block: bool,
    ) -> Vec<StageDone> {
        let mut out = Vec::new();
        while self
            .inflight
            .peek()
            .is_some_and(|r| r.0.done_us <= now_us + 1e-9)
        {
            let Reverse(e) = self.inflight.pop().expect("peeked entry");
            out.push(StageDone {
                ticket: e.ticket,
                done_us: e.done_us,
                worker: e.worker,
                group: e.group,
                run: e.run,
            });
        }
        out
    }

    fn next_done_us(&self) -> Option<f64> {
        self.inflight.peek().map(|r| r.0.done_us)
    }

    fn gate_inputs(
        &self,
        placement: Option<&Placement>,
        group: u64,
        now_us: f64,
    ) -> (f64, Option<f64>) {
        match placement {
            // the true wait: queued device time on the least-loaded replica
            Some(p) => placed_gate_inputs(p, group, |w| {
                (self.free_at[w] - now_us).max(0.0)
            }),
            None => (1.0, Some((self.free_at[0] - now_us).max(0.0))),
        }
    }
}

/// Wall-clock inline execution on the driver thread: the launch runs to
/// completion inside `launch` and is handed back at the next poll with
/// the post-execution wall stamp.
pub struct InlineStage {
    ready: Vec<(u64, u64, PackRun)>,
}

impl InlineStage {
    /// A fresh inline stage.
    pub fn new() -> Self {
        InlineStage { ready: Vec::new() }
    }
}

impl Default for InlineStage {
    fn default() -> Self {
        Self::new()
    }
}

impl<X: ModelBackend> LaunchStage<X> for InlineStage {
    fn launch(
        &mut self,
        jit: &mut ServeJit<X>,
        _slots: &[ModelSlot],
        _placement: Option<&Placement>,
        group: u64,
        l: PendingLaunch,
        _now_us: f64,
    ) {
        let run = jit.run_issued(l.ticket);
        self.ready.push((l.ticket, group, run));
    }

    fn poll(
        &mut self,
        _placement: Option<&Placement>,
        now_us: f64,
        _block: bool,
    ) -> Vec<StageDone> {
        self.ready
            .drain(..)
            .map(|(ticket, group, run)| StageDone {
                ticket,
                done_us: now_us,
                worker: 0,
                group,
                run,
            })
            .collect()
    }

    fn gate_inputs(
        &self,
        _placement: Option<&Placement>,
        _group: u64,
        _now_us: f64,
    ) -> (f64, Option<f64>) {
        (1.0, None)
    }
}

/// Wall-clock concurrent launches on a [`StatefulPool`]: each worker owns
/// its own backend; results come home on a channel. The stage books an
/// estimated backlog per worker at launch (conservative: head-job
/// progress is not subtracted — a wall-clock driver cannot observe it)
/// and releases it at completion; that booked backlog is the gate's
/// device signal.
pub struct PoolStage<'p, W> {
    pool: &'p StatefulPool<W>,
    res_tx: mpsc::Sender<(u64, Result<ModelExec, String>)>,
    res_rx: mpsc::Receiver<(u64, Result<ModelExec, String>)>,
    /// launch ticket → (worker, group, booked estimate µs)
    ticket_route: BTreeMap<u64, (usize, u64, f64)>,
    worker_backlog: Vec<f64>,
}

impl<'p, W> PoolStage<'p, W> {
    /// A stage over an existing pool.
    pub fn new(pool: &'p StatefulPool<W>) -> Self {
        // lint: LINT004 result channel; at most one message per booked launch
        let (res_tx, res_rx) = mpsc::channel();
        let workers = pool.workers();
        PoolStage {
            pool,
            res_tx,
            res_rx,
            ticket_route: BTreeMap::new(),
            worker_backlog: vec![0.0; workers],
        }
    }

    fn convert(
        &mut self,
        placement: Option<&Placement>,
        now_us: f64,
        (ticket, result): (u64, Result<ModelExec, String>),
    ) -> StageDone {
        let (worker, group, booked) =
            self.ticket_route.remove(&ticket).unwrap_or((0, 0, 0.0));
        if let Some(b) = self.worker_backlog.get_mut(worker) {
            *b = (*b - booked).max(0.0);
        }
        let mut run = match result {
            Ok(exec) => PackRun {
                duration_us: exec.duration_us,
                executed: exec.batch,
                ok: true,
                device_class: 0,
            },
            Err(e) => {
                crate::util::logging::emit(
                    crate::util::logging::Level::Error,
                    format_args!("pooled execute failed: {e}"),
                );
                PackRun {
                    duration_us: 0.0,
                    executed: 0,
                    ok: false,
                    device_class: 0,
                }
            }
        };
        if let Some(p) = placement {
            run.device_class = p.topo.class_of(worker);
        }
        StageDone {
            ticket,
            done_us: now_us,
            worker,
            group,
            run,
        }
    }
}

impl<W: ModelBackend + 'static, X: ModelBackend> LaunchStage<X> for PoolStage<'_, W> {
    fn launch(
        &mut self,
        jit: &mut ServeJit<X>,
        slots: &[ModelSlot],
        placement: Option<&Placement>,
        group: u64,
        l: PendingLaunch,
        _now_us: f64,
    ) {
        // route through the placement table to the least-loaded replica
        // of the launch's group (legacy group-hash when unplaced)
        let worker = match placement {
            Some(p) => {
                let loads: Vec<f64> = (0..self.pool.workers())
                    .map(|w| self.pool.in_flight_of(w) as f64)
                    .collect();
                p.table.route(group, &loads)
            }
            None => group as usize % self.pool.workers(),
        };
        let est_routed = match placement {
            Some(p) => jit.executor().estimate_group_on_class_us(
                group,
                p.topo.class_of(worker),
                l.pack.ops.len() as u32,
            ),
            None => l.est_us,
        };
        jit.reprice_pending(l.ticket, est_routed);
        if let Some(b) = self.worker_backlog.get_mut(worker) {
            *b += est_routed;
        }
        self.ticket_route.insert(l.ticket, (worker, group, est_routed));
        let model = slots[group as usize].name.clone();
        let rows: Vec<Vec<f32>> = jit
            .payloads_of(&l.pack.ops)
            .into_iter()
            .cloned()
            .collect();
        let res_tx = self.res_tx.clone();
        let ticket = l.ticket;
        self.pool.submit_to(worker, move |backend: &mut W| {
            let r = backend.execute(&model, &rows).map_err(|e| e.to_string());
            let _ = res_tx.send((ticket, r));
        });
    }

    fn poll(
        &mut self,
        placement: Option<&Placement>,
        now_us: f64,
        block: bool,
    ) -> Vec<StageDone> {
        let mut out = Vec::new();
        // block briefly when only results remain (arrival channel gone) —
        // avoids a busy spin on the disconnected intake
        if block && !self.ticket_route.is_empty() {
            if let Ok(r) = self.res_rx.recv_timeout(Duration::from_micros(500)) {
                out.push(self.convert(placement, now_us, r));
            }
        }
        while let Ok(r) = self.res_rx.try_recv() {
            out.push(self.convert(placement, now_us, r));
        }
        out
    }

    fn gate_inputs(
        &self,
        placement: Option<&Placement>,
        group: u64,
        _now_us: f64,
    ) -> (f64, Option<f64>) {
        pool_gate_inputs(placement, self.pool.workers(), &self.worker_backlog, group)
    }
}

// ---------------------------------------------------------------------------
// Requests in flight between threads / layers
// ---------------------------------------------------------------------------

/// One trace request lowered to engine terms.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// True arrival instant on the trace clock, µs.
    pub at_us: f64,
    /// Tenant id.
    pub tenant: u32,
    /// Coalescing group (model index).
    pub group: u64,
    /// Absolute deadline on the trace clock, µs.
    pub deadline_us: f64,
    /// Request id (row-payload seed).
    pub id: u64,
    /// SLO class (from the issuing tenant's spec).
    pub class: SloClass,
}

/// Lower a trace onto the run's group table, in arrival order.
pub fn trace_arrivals(trace: &Trace, index: &BTreeMap<String, u64>) -> Vec<Arrival> {
    trace
        .requests
        .iter()
        .map(|r| Arrival {
            at_us: r.arrival_us,
            tenant: r.tenant,
            group: index[&r.model],
            deadline_us: r.deadline_us,
            id: r.id,
            class: r.class,
        })
        .collect()
}

/// One client request in flight from the generator (client side) or the
/// network intake to the admission gate — sync or frontend.
pub(crate) struct Incoming {
    pub tenant: u32,
    pub group: u64,
    pub slo_us: f64,
    pub class: SloClass,
    pub arrival: Instant,
    pub row: Vec<f32>,
    /// Intake reply token (`(batch << 16) | op index`); 0 for requests
    /// born in-process (generator, tests) — never tracked or replied to.
    pub token: u64,
}

/// An accepted, pre-priced request in flight from the frontend stage to
/// the engine. The gate decision is already made; the engine only
/// timestamps it into the window (backpressure backstop aside).
pub(crate) struct Admitted {
    pub stream: StreamId,
    pub group: u64,
    pub tenant: u32,
    pub slo_us: f64,
    pub class: SloClass,
    pub arrival: Instant,
    pub row: Vec<f32>,
    pub token: u64,
}

/// What the frontend stage sends the engine.
pub(crate) enum FromFrontend {
    /// An accepted request, to be drained into the window.
    Admitted(Admitted),
    /// A rejected request, with the reason the gate shed it. The engine
    /// folds the reason into [`ServeMetrics::rejects_by_reason`] and
    /// routes the terminal outcome to the wire sink so a network caller
    /// learns *why* instead of watching the request vanish. (Per-class
    /// reject totals stay on [`FrontendReport`]; only the reason
    /// decomposition rides this record.)
    Rejected {
        token: u64,
        class: SloClass,
        reason: RejectReason,
    },
    /// Stream ids the gate retired at an epoch boundary (idle a full
    /// epoch, accepts fully drained): the engine drops its mirrored
    /// per-stream drain counters. Ids are never reused, so a late Retire
    /// can never collide with live accounting.
    Retire(Vec<u32>),
}

/// A terminal per-op outcome routed from the engine back to the intake
/// reply router. `token` is the intake correlation token (never 0 here).
pub(crate) struct OpEvent {
    pub token: u64,
    pub outcome: OpOutcome,
}

/// How a wire-born op ended.
pub(crate) enum OpOutcome {
    Done { latency_us: f64, met_deadline: bool },
    Failed,
    Rejected(RejectReason),
}

/// Correlates wire-born requests through the engine: `tokens` maps live
/// window op ids back to intake reply tokens; `tx` routes terminal
/// outcomes to the reply router. Default (empty map, no sink) for the
/// in-process drive modes — token 0 marks a non-wire request and is
/// never tracked or emitted.
#[derive(Default)]
pub(crate) struct WireSink {
    tokens: HashMap<OpId, u64>,
    tx: Option<mpsc::Sender<OpEvent>>,
    /// Launch-log auditor, if attached: every terminal outcome routed
    /// through here also lands as a `reply` event, and the admission
    /// paths that already carry the sink stamp admit/reject events.
    audit: Option<Arc<AuditLog>>,
}

impl WireSink {
    fn emit(&self, token: u64, outcome: OpOutcome) {
        if token == 0 {
            return;
        }
        if let Some(log) = &self.audit {
            log.reply(token);
        }
        if let Some(tx) = &self.tx {
            // a failed send means the reply router is gone (shutdown):
            // the outcome is dropped with it, nothing to do
            let _ = tx.send(OpEvent { token, outcome });
        }
    }
}

/// The post-accept tail shared by both gates (bundled so the two call
/// sites cannot drift): what the engine needs to timestamp an accepted
/// request into the window.
struct Accepted {
    stream: StreamId,
    group: u64,
    tenant: u32,
    slo_us: f64,
    class: SloClass,
    arrival_us: f64,
    independent: bool,
    row: Vec<f32>,
    token: u64,
}

/// One request at the synchronous admission gate (bundled so call sites
/// cannot transpose the adjacent time/flag fields).
pub(crate) struct AdmitReq {
    pub group: u64,
    pub tenant: u32,
    pub arrival_us: f64,
    pub deadline_us: f64,
    pub class: SloClass,
    pub independent: bool,
    /// Effective drain parallelism of the group's serving workers (speed-
    /// weighted replica count from [`drain_parallelism`]; 1.0 for the
    /// single-device drive modes) — the drain estimate's divisor.
    pub parallelism: f64,
    /// Measured backlog on the group's least-loaded replica, µs (device
    /// timelines or booked pool estimates). `Some` replaces the JIT's
    /// in-flight estimate term, which cannot see device queueing; `None`
    /// for drive modes without a measured signal.
    pub device_backlog_us: Option<f64>,
    pub row: Vec<f32>,
    /// Intake reply token; 0 for in-process requests.
    pub token: u64,
}

/// A (tenant, model-group) pair is one stream of execution. Stream ids
/// are interned per run in first-appearance order (no bit packing —
/// arbitrary tenant ids can never collide).
fn intern_stream(
    streams: &mut BTreeMap<(u32, u64), u32>,
    tenant: u32,
    group: u64,
) -> StreamId {
    let next = streams.len() as u32;
    StreamId(*streams.entry((tenant, group)).or_insert(next))
}

fn record_completion(metrics: &mut ServeMetrics, c: &OpCompletion) {
    let tenant = c.op.tag as u32;
    if c.failed {
        metrics.drop_request(tenant, c.op.class);
    } else {
        metrics.complete(tenant, c.op.class, c.latency_us(), c.met_deadline);
    }
}

/// Build the dispatch request for an accepted serving request and submit
/// it at its true arrival; the window backstop sheds on overflow
/// (recorded as a drop). The ONE request-construction path behind the
/// synchronous gate and the frontend drain.
fn submit_accepted<X: ModelBackend>(
    jit: &mut ServeJit<X>,
    admission: &Admission,
    metrics: &mut ServeMetrics,
    slots: &[ModelSlot],
    wire: &mut WireSink,
    a: Accepted,
) {
    let slot = &slots[a.group as usize];
    let req = DispatchRequest::new(
        a.stream,
        KernelDesc::gemm(1, slot.d_in as u32, 1),
        a.slo_us,
    )
    .with_group(a.group)
    .with_tag(a.tenant as u64)
    .with_class(a.class)
    .with_independent(a.independent);
    match jit.submit_at(req, a.arrival_us, a.row) {
        Some(id) => {
            if a.token != 0 {
                wire.tokens.insert(id, a.token);
            }
            if let Some(log) = &wire.audit {
                // post-submit window counts are the auditor's ground
                // truth: a gate that over-admitted shows up here even if
                // its own (possibly stale) pricing view looked legal
                log.admit(
                    a.stream.0,
                    a.group,
                    a.class.name(),
                    jit.window.pending_in_group(a.group),
                    jit.window.inflight_in_group(a.group),
                    admission.cap_of(a.class),
                );
            }
        }
        None => {
            // window full: the backpressure backstop sheds the request
            metrics.drop_request(a.tenant, a.class);
            metrics.reject_reason(RejectReason::QueueFull, a.class);
            wire.emit(a.token, OpOutcome::Rejected(RejectReason::QueueFull));
            if let Some(log) = &wire.audit {
                log.reject(a.class.name(), RejectReason::QueueFull.name());
            }
        }
    }
}

/// Synchronous admission for one request; on Accept, submits it into the
/// JIT (window backpressure sheds as a backstop). Records drops.
///
/// Pricing goes through the same [`frontend::GroupView`] the async
/// frontend stage consumes, built synchronously from live JIT state — see
/// [`frontend::GroupView::drain_est_us`] for the drain model and
/// [`Admission::decide`] for the separate queued/in-flight contracts. One
/// pricing implementation behind both gates means they cannot disagree on
/// identical state.
pub(crate) fn admit_request<X: ModelBackend>(
    jit: &mut ServeJit<X>,
    streams: &mut BTreeMap<(u32, u64), u32>,
    admission: &Admission,
    metrics: &mut ServeMetrics,
    slots: &[ModelSlot],
    wire: &mut WireSink,
    r: AdmitReq,
) -> Option<RejectReason> {
    let AdmitReq {
        group,
        tenant,
        arrival_us,
        deadline_us,
        class,
        independent,
        parallelism,
        device_backlog_us,
        row,
        token,
    } = r;
    let stream = intern_stream(streams, tenant, group);
    // independent-mode pricing never reads the per-stream depth list, so
    // the synchronous gate skips that window scan
    let gview = frontend::snapshot_group(
        jit,
        group,
        parallelism,
        device_backlog_us,
        !independent,
    );
    let greq = GateRequest {
        stream,
        independent,
        deadline_us,
        class,
    };
    if gview.decide(admission, &greq, GateExtras::default(), jit.now_us) == Admit::Reject
    {
        metrics.gate_decision(class, false);
        metrics.drop_request(tenant, class);
        metrics.reject_reason(RejectReason::QueueFull, class);
        wire.emit(token, OpOutcome::Rejected(RejectReason::QueueFull));
        if let Some(log) = &wire.audit {
            log.reject(class.name(), RejectReason::QueueFull.name());
        }
        return Some(RejectReason::QueueFull);
    }
    metrics.gate_decision(class, true);
    submit_accepted(
        jit,
        admission,
        metrics,
        slots,
        wire,
        Accepted {
            stream,
            group,
            tenant,
            slo_us: deadline_us - arrival_us,
            class,
            arrival_us,
            independent,
            row,
            token,
        },
    );
    None
}

/// The admission frontend stage's thread body: drain the intake channel,
/// price each request against the latest published [`AdmissionView`],
/// forward accepts to the engine, turn rejects around locally, and retire
/// idle fully-drained streams at epoch boundaries. Exits when the intake
/// side disconnects; its thread-local accounting ([`FrontendReport`])
/// comes home through the stage's join.
fn frontend_loop(
    intake_rx: mpsc::Receiver<Incoming>,
    acc_tx: mpsc::Sender<FromFrontend>,
    cell: Arc<ViewCell>,
    admission: Admission,
    mut shaper: TenantShaper,
    groups: usize,
    independent: bool,
    t0: Instant,
) -> FrontendReport {
    let mut gate = FrontendGate::new(admission, groups);
    let mut report = FrontendReport::default();
    let mut last_epoch = Instant::now();
    loop {
        let first = match intake_rx.recv_timeout(Duration::from_micros(500)) {
            Ok(inc) => Some(inc),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        if let Some(first) = first {
            let mut batch = vec![first];
            while let Ok(inc) = intake_rx.try_recv() {
                batch.push(inc);
            }
            for inc in batch {
                let view = cell.load();
                let now_us = t0.elapsed().as_secs_f64() * 1e6;
                let arrival_us =
                    inc.arrival.saturating_duration_since(t0).as_secs_f64() * 1e6;
                let stream = gate.intern(inc.tenant, inc.group);
                // the token bucket is consulted before pricing: a shaped
                // request never reaches the scheduler, so a saturating
                // tenant is invisible to everyone else's admission prices
                let shaped = !shaper.admit(inc.tenant, now_us);
                let greq = GateRequest {
                    stream,
                    independent,
                    deadline_us: arrival_us + inc.slo_us,
                    class: inc.class,
                };
                let reason = if shaped {
                    Some(RejectReason::RateLimited)
                } else {
                    gate.decide_reason(&view, inc.group, &greq, now_us)
                };
                report.decisions += 1;
                report
                    .admission_latency
                    .record_us(inc.arrival.elapsed().as_secs_f64() * 1e6);
                if view.published.elapsed().as_secs_f64() * 1e6 > STALE_VIEW_US {
                    report.stale_decisions += 1;
                }
                // a send can only fail at shutdown (engine gone): the
                // request is shed, counted like any other reject
                let accepted = reason.is_none()
                    && acc_tx
                        .send(FromFrontend::Admitted(Admitted {
                            stream,
                            group: inc.group,
                            tenant: inc.tenant,
                            slo_us: inc.slo_us,
                            class: inc.class,
                            arrival: inc.arrival,
                            row: inc.row,
                            token: inc.token,
                        }))
                        .is_ok();
                let ci = inc.class.index();
                if accepted {
                    report.accepts_by_class[ci] += 1;
                } else {
                    report.rejects_by_class[ci] += 1;
                    if shaped {
                        report.shaped_by_class[ci] += 1;
                    }
                    *report.drops.entry(inc.tenant).or_insert(0) += 1;
                    // the reason record rides to the engine so intake can
                    // answer the wire caller and metrics can decompose
                    // the shed; QueueFull covers the shutdown-send edge
                    let _ = acc_tx.send(FromFrontend::Rejected {
                        token: inc.token,
                        class: inc.class,
                        reason: reason.unwrap_or(RejectReason::QueueFull),
                    });
                }
            }
        }
        // epoch boundary: retire (tenant, model) streams idle for a full
        // epoch whose accepts the engine has fully drained, and tell the
        // engine to drop its mirrored drain counters
        if last_epoch.elapsed().as_secs_f64() * 1e6 >= FRONTEND_EPOCH_US {
            last_epoch = Instant::now();
            let retired = gate.advance_epoch(&cell.load());
            if !retired.is_empty() {
                let _ = acc_tx.send(FromFrontend::Retire(retired));
            }
        }
    }
    report
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Engine options that are plain values (the trait-shaped options — clock,
/// stage, placement — are separate constructor arguments).
pub struct EngineConfig {
    /// Admission policy (both gates).
    pub admission: Admission,
    /// Per-tenant rate limits: tenant → (rate req/s, burst). Applied by
    /// whichever gate owns admission (frontend stage or sync gate) via a
    /// [`TenantShaper`]; tenants without an entry pass unshaped.
    pub tenant_rates: BTreeMap<u32, (f64, f64)>,
    /// Mark requests independent within their stream (stateless serving).
    pub independent_streams: bool,
    /// Run admission on the dedicated frontend thread (wall clock only;
    /// ignored — and asserted off — under a virtual clock).
    pub frontend: bool,
    /// Policy name for the report.
    pub policy: &'static str,
}

/// The serving engine: ONE admit → issue → launch → complete → rebalance
/// loop, parameterized by a [`Clock`] and a [`LaunchStage`], with
/// [`Placement`] and the admission frontend as orthogonal options. Every
/// `Server::replay*` / `Server::run_realtime*` drive mode is a thin
/// constructor over this.
pub struct Engine<X: ModelBackend, C: Clock, S: LaunchStage<X>> {
    jit: ServeJit<X>,
    clock: C,
    stage: S,
    placement: Option<Placement>,
    slots: Vec<ModelSlot>,
    admission: Admission,
    /// Per-tenant rate limits (rebuilt into the frontend stage's own
    /// shaper when admission moves to that thread).
    tenant_rates: BTreeMap<u32, (f64, f64)>,
    /// The sync gate's shaper (virtual + wall-sync paths).
    shaper: TenantShaper,
    independent: bool,
    frontend: bool,
    policy_name: &'static str,
    metrics: ServeMetrics,
    /// Sync-gate stream interning (virtual + wall-sync paths).
    streams: BTreeMap<(u32, u64), u32>,
    /// Cumulative frontend-accepted requests drained into the window, per
    /// group — published in every snapshot so the frontend nets them off
    /// its own accept counters.
    drained: Vec<u64>,
    /// The same cumulative drain count per stream id; compacted when the
    /// gate retires a stream ([`FromFrontend::Retire`]).
    drained_by_stream: BTreeMap<u32, u64>,
    /// Wire-request correlation: reply tokens for live ops plus the
    /// outcome sink intake's reply router listens on. Inert (empty,
    /// no sink) for in-process drive modes.
    wire: WireSink,
    /// Launch-log auditor ([`crate::analysis::audit`]), if attached:
    /// the loop stamps launch/complete/rebalance events, the wire sink
    /// mirrors replies, and the gates stamp admit/reject events.
    audit: Option<Arc<AuditLog>>,
    /// Rebalance epochs stamped into the launch log (monotonic per run).
    audit_epoch: u64,
    /// The scheduler's next wake from the last `issue_and_launch` —
    /// bounds the wall loop's channel wait so a pending coalescing
    /// window fires on time instead of on the next 500µs poll tick.
    wake_hint_us: Option<f64>,
    view_seq: u64,
    view_dirty: bool,
    /// The estimator generation the last published snapshot was built
    /// against: when a variant changes answering tier (e.g. a warm-started
    /// Tuned entry overtaken by the first real Measurement) *without* a
    /// completion in the same iteration, this is what forces the next
    /// snapshot so the frontend's memoized `est_by_n` tables refresh.
    last_gen: u64,
}

/// The wall-clock intake state: either the raw client channel (sync gate)
/// or the frontend link.
struct WallIntake {
    t0: Instant,
    sync_rx: Option<mpsc::Receiver<Incoming>>,
    fe: Option<FrontendLink>,
    disconnected: bool,
}

struct FrontendLink {
    acc_rx: mpsc::Receiver<FromFrontend>,
    cell: Arc<ViewCell>,
    stage: Stage<FrontendReport>,
    last_publish: Instant,
}

impl<X, C, S> Engine<X, C, S>
where
    X: ModelBackend,
    C: Clock,
    S: LaunchStage<X>,
{
    /// A new engine over a configured JIT, clock, stage, and options.
    pub fn new(
        mut jit: ServeJit<X>,
        clock: C,
        stage: S,
        placement: Option<Placement>,
        slots: Vec<ModelSlot>,
        cfg: EngineConfig,
    ) -> Self {
        // decide latency is measured in wall time even on virtual-clock
        // engines: the histogram tracks scheduler overhead, not the
        // simulated timeline
        jit.decide_clock = Some(decide_clock_ns);
        let groups = slots.len();
        let last_gen = jit.executor().estimator_generation();
        let mut engine = Engine {
            jit,
            clock,
            stage,
            placement,
            slots,
            admission: cfg.admission,
            shaper: TenantShaper::from_rates(&cfg.tenant_rates),
            tenant_rates: cfg.tenant_rates,
            independent: cfg.independent_streams,
            frontend: cfg.frontend,
            policy_name: cfg.policy,
            metrics: ServeMetrics::default(),
            streams: BTreeMap::new(),
            drained: vec![0; groups],
            drained_by_stream: BTreeMap::new(),
            wire: WireSink::default(),
            audit: None,
            audit_epoch: 0,
            wake_hint_us: None,
            view_seq: 0,
            view_dirty: false,
            last_gen,
        };
        if let Some(p) = &engine.placement {
            engine
                .jit
                .executor_mut()
                .set_class_speeds(p.topo.class_speeds());
            repin_group_classes(
                engine.jit.executor_mut(),
                &p.table,
                &p.topo,
                engine.slots.len() as u64,
            );
            if p.report_devices {
                for w in p.topo.workers() {
                    engine.metrics.ensure_device(w.worker, w.spec.name);
                }
            }
        }
        engine
    }

    /// Route wire-born ops' terminal outcomes (done/failed/rejected,
    /// keyed by intake token) to `tx` — the network intake's reply
    /// router. Requests with token 0 are unaffected.
    pub(crate) fn with_reply_sink(mut self, tx: mpsc::Sender<OpEvent>) -> Self {
        self.wire.tx = Some(tx);
        self
    }

    /// Stream structured launch/admission events to `log` as JSONL for
    /// offline replay by `vliwd audit` (see [`crate::analysis::audit`]).
    /// `None` keeps every emission off the hot path.
    pub(crate) fn with_audit(mut self, log: Option<Arc<AuditLog>>) -> Self {
        self.wire.audit = log.clone();
        self.audit = log;
        self
    }

    /// Replay `arrivals` on the virtual clock: deterministic given a
    /// deterministic backend, stage, and placement. Returns the report
    /// and the final placement table (None for unplaced runs).
    pub fn run_virtual(mut self, arrivals: &[Arrival]) -> (ServeReport, Option<PlacementTable>) {
        debug_assert!(self.clock.is_virtual(), "virtual run needs a virtual clock");
        debug_assert!(!self.frontend, "virtual runs keep the synchronous gate");
        let mut next = 0usize;
        loop {
            // 1. admit everything that has arrived (true arrival times)
            self.drain_virtual(arrivals, &mut next);
            // 2. issue every launch the policy allows; the stage routes
            // and queues (or executes) each one
            let wake = self.issue_and_launch();
            // 3. advance the virtual clock to the next event and fold it in
            let next_arrival = arrivals.get(next).map(|a| a.at_us);
            let next_done = self.stage.next_done_us();
            let t = [next_done, next_arrival, wake]
                .iter()
                .flatten()
                .fold(f64::INFINITY, |m, v| m.min(*v));
            if !t.is_finite() {
                debug_assert!(self.jit.window.is_empty(), "deadlocked window");
                break;
            }
            self.clock.advance_to(t);
            self.jit.advance_to(t);
            // 4. fold completions now due (deterministic (done, ticket)
            // order), then rebalance between observation windows
            self.settle(false);
        }
        self.metrics.span_us = self.jit.now_us;
        self.metrics.jit = self.jit.stats.clone();
        self.metrics.estimator = self.jit.executor().estimator_stats();
        let report = ServeReport {
            metrics: self.metrics,
            policy: self.policy_name,
            tuned: self.jit.executor().export_tuned(),
        };
        (report, self.placement.map(|p| p.table))
    }

    /// Serve `arrivals` on the wall clock, paced by a generator thread
    /// (trace time compressed by `speedup`), admission on the frontend
    /// stage thread or synchronously per [`EngineConfig::frontend`].
    pub fn run_wall(self, arrivals: Vec<Arrival>, speedup: f64) -> ServeReport {
        debug_assert!(!self.clock.is_virtual(), "wall run needs the wall clock");
        let d_ins: Vec<usize> = self.slots.iter().map(|s| s.d_in).collect();
        let gen_reqs: Vec<(f64, u32, u64, f64, u64, SloClass)> = arrivals
            .iter()
            .map(|a| {
                (
                    a.at_us / speedup,
                    a.tenant,
                    a.group,
                    a.deadline_us - a.at_us,
                    a.id,
                    a.class,
                )
            })
            .collect();
        // lint: LINT004 trace generator paces sends; depth bounded by the trace
        let (tx, rx) = mpsc::channel::<Incoming>();
        let gen = std::thread::spawn(move || {
            let g0 = Instant::now();
            for (at_us, tenant, group, slo, id, class) in gen_reqs {
                let target = Duration::from_micros(at_us as u64);
                let elapsed = g0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                let d_in = d_ins[group as usize];
                let _ = tx.send(Incoming {
                    tenant,
                    group,
                    slo_us: slo,
                    class,
                    arrival: Instant::now(),
                    row: golden::gen_hash01(d_in, id.wrapping_mul(7919)),
                    token: 0,
                });
            }
        });
        let report = self.run_wall_rx(rx);
        // the wall loop only exits once the intake side disconnects, so
        // the generator has already sent its last request
        gen.join().expect("generator thread");
        report
    }

    /// The wall-clock engine body over an externally-owned intake
    /// channel: `run_wall` feeds it from the trace generator; the network
    /// intake ([`crate::serve::intake`]) feeds it from socket shards.
    /// Runs until every sender of `rx` is dropped and the window drains.
    pub(crate) fn run_wall_rx(mut self, rx: mpsc::Receiver<Incoming>) -> ServeReport {
        debug_assert!(!self.clock.is_virtual(), "wall run needs the wall clock");
        let t0 = self.clock.origin();
        let mut intake = if self.frontend {
            // lint: LINT004 frontend accepts; bounded by the admission gate itself
            let (acc_tx, acc_rx) = mpsc::channel::<FromFrontend>();
            let cell = ViewCell::new(self.build_view(0));
            let fe_cell = Arc::clone(&cell);
            let fe_admission = self.admission.clone();
            let fe_shaper = TenantShaper::from_rates(&self.tenant_rates);
            let n_groups = self.slots.len();
            let independent = self.independent;
            let stage = Stage::spawn("vliw-frontend", move || {
                frontend_loop(
                    rx,
                    acc_tx,
                    fe_cell,
                    fe_admission,
                    fe_shaper,
                    n_groups,
                    independent,
                    t0,
                )
            });
            WallIntake {
                t0,
                sync_rx: None,
                fe: Some(FrontendLink {
                    acc_rx,
                    cell,
                    stage,
                    last_publish: Instant::now(),
                }),
                disconnected: false,
            }
        } else {
            WallIntake {
                t0,
                sync_rx: Some(rx),
                fe: None,
                disconnected: false,
            }
        };

        loop {
            // 1. pace on the intake channel; admit (sync gate) or drain
            // frontend-accepted requests into the window
            self.drain_wall(&mut intake);
            // 2. issue + launch (inline stages execute and fold here);
            // the wake hint bounds the next iteration's channel wait
            self.wake_hint_us = self.issue_and_launch();
            // 3. fold finished pool launches; log; rebalance
            let block = intake.disconnected && self.jit.inflight_launches() > 0;
            self.settle(block);
            // 4. publish a fresh admission snapshot — after this
            // iteration's submits, launches and completions, so the view
            // only ever lags reality, never leads it. Skipped on idle
            // ticks (state unchanged ⇒ the last view is still exact),
            // with a heartbeat so healthy-idle never reads as stale.
            if let Some(fe) = intake.fe.as_mut() {
                let heartbeat =
                    fe.last_publish.elapsed().as_secs_f64() * 1e6 > STALE_VIEW_US / 2.0;
                if self.view_dirty || heartbeat {
                    self.view_seq += 1;
                    let view_seq = self.view_seq;
                    let v = self.build_view(view_seq);
                    fe.cell.publish(v);
                    self.view_dirty = false;
                    fe.last_publish = Instant::now();
                }
            }
            if intake.disconnected
                && self.jit.window.is_empty()
                && self.jit.inflight_launches() == 0
            {
                break;
            }
        }
        if let Some(fe) = intake.fe {
            // the frontend exits once the upstream intake disconnects
            // and it has drained; fold its thread-local accounting in
            drop(fe.acc_rx);
            self.metrics.merge_frontend(&fe.stage.join());
        }
        // ops that left the window without a terminal completion (e.g.
        // evicted mid-flight at shutdown) must still answer their batch:
        // flush the leftovers as failures so no wire client waits forever
        let leftovers: Vec<u64> = self.wire.tokens.values().copied().collect();
        self.wire.tokens.clear();
        for token in leftovers {
            self.wire.emit(token, OpOutcome::Failed);
        }
        self.metrics.span_us = self.clock.now_us();
        self.metrics.jit = self.jit.stats.clone();
        self.metrics.estimator = self.jit.executor().estimator_stats();
        ServeReport {
            metrics: self.metrics,
            policy: self.policy_name,
            tuned: self.jit.executor().export_tuned(),
        }
    }

    // -- loop body helpers ---------------------------------------------------

    fn drain_virtual(&mut self, arrivals: &[Arrival], next: &mut usize) {
        while *next < arrivals.len() && arrivals[*next].at_us <= self.jit.now_us + 1e-9 {
            let a = arrivals[*next];
            *next += 1;
            let row =
                golden::gen_hash01(self.slots[a.group as usize].d_in, a.id.wrapping_mul(7919));
            self.admit_sync(a.group, a.tenant, a.class, a.at_us, a.deadline_us, 0, row);
        }
    }

    /// How long the wall loop may block on its intake channel this
    /// iteration: the fixed 500µs poll, shortened when the scheduler's
    /// wake hint (a pending coalescing window, typically) is due sooner.
    /// A channel send still interrupts the wait immediately — this bound
    /// only keeps *scheduler* deadlines from quantizing to the poll tick.
    fn drain_wait(&self) -> Duration {
        let us = match self.wake_hint_us {
            Some(at) => (at - self.clock.now_us()).clamp(20.0, 500.0),
            None => 500.0,
        };
        Duration::from_micros(us as u64)
    }

    fn drain_wall(&mut self, intake: &mut WallIntake) {
        // once the upstream side is gone the channel stays empty — pace
        // the loop with a short sleep instead of spinning on it
        if intake.disconnected {
            std::thread::sleep(self.drain_wait().min(Duration::from_micros(200)));
        }
        if let Some(rx) = &intake.sync_rx {
            let mut arrivals: Vec<Incoming> = Vec::new();
            if !intake.disconnected {
                match rx.recv_timeout(self.drain_wait()) {
                    Ok(inc) => {
                        arrivals.push(inc);
                        while let Ok(inc) = rx.try_recv() {
                            arrivals.push(inc);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        intake.disconnected = true;
                    }
                }
            }
            self.jit.advance_to(self.clock.now_us());
            for inc in arrivals {
                // the synchronous gate decides at drain time: the
                // arrival→decision latency IS the channel wait
                self.metrics
                    .sync_admission_decision(inc.arrival.elapsed().as_secs_f64() * 1e6);
                let arrival_us =
                    inc.arrival.saturating_duration_since(intake.t0).as_secs_f64() * 1e6;
                self.admit_sync(
                    inc.group,
                    inc.tenant,
                    inc.class,
                    arrival_us,
                    arrival_us + inc.slo_us,
                    inc.token,
                    inc.row,
                );
            }
        } else if let Some(fe) = &intake.fe {
            let mut msgs: Vec<FromFrontend> = Vec::new();
            if !intake.disconnected {
                match fe.acc_rx.recv_timeout(self.drain_wait()) {
                    Ok(m) => {
                        msgs.push(m);
                        while let Ok(m) = fe.acc_rx.try_recv() {
                            msgs.push(m);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        intake.disconnected = true;
                    }
                }
            }
            self.jit.advance_to(self.clock.now_us());
            for m in msgs {
                match m {
                    FromFrontend::Admitted(adm) => {
                        self.view_dirty = true;
                        // how long the accepted request sat between
                        // threads before being priced into the window
                        self.metrics
                            .frontend_wait
                            .record_us(adm.arrival.elapsed().as_secs_f64() * 1e6);
                        // drain accounting advances whether or not the
                        // window backstop sheds — the frontend nets these
                        // counters off its cumulative accepts either way
                        self.drained[adm.group as usize] += 1;
                        *self.drained_by_stream.entry(adm.stream.0).or_insert(0) += 1;
                        let arrival_us = adm
                            .arrival
                            .saturating_duration_since(intake.t0)
                            .as_secs_f64()
                            * 1e6;
                        submit_accepted(
                            &mut self.jit,
                            &self.admission,
                            &mut self.metrics,
                            &self.slots,
                            &mut self.wire,
                            Accepted {
                                stream: adm.stream,
                                group: adm.group,
                                tenant: adm.tenant,
                                slo_us: adm.slo_us,
                                class: adm.class,
                                arrival_us,
                                independent: self.independent,
                                row: adm.row,
                                token: adm.token,
                            },
                        );
                    }
                    FromFrontend::Rejected {
                        token,
                        class,
                        reason,
                    } => {
                        // per-class reject totals already live on the
                        // frontend's report; only the reason decomposition
                        // and the wire reply land here
                        self.metrics.reject_reason(reason, class);
                        self.wire.emit(token, OpOutcome::Rejected(reason));
                        if let Some(log) = &self.wire.audit {
                            log.reject(class.name(), reason.name());
                        }
                    }
                    FromFrontend::Retire(ids) => {
                        for id in ids {
                            self.drained_by_stream.remove(&id);
                        }
                    }
                }
            }
        }
    }

    fn admit_sync(
        &mut self,
        group: u64,
        tenant: u32,
        class: SloClass,
        arrival_us: f64,
        deadline_us: f64,
        token: u64,
        row: Vec<f32>,
    ) {
        // the sync gate owns the shaper here — same contract as the
        // frontend stage: a shaped request is rejected before pricing.
        // Clocked on the JIT clock so the same bucket works under the
        // virtual and wall clocks (both advance it before draining).
        if !self.shaper.admit(tenant, self.jit.now_us) {
            self.metrics.shaped_request(tenant, class);
            self.metrics.reject_reason(RejectReason::RateLimited, class);
            self.wire
                .emit(token, OpOutcome::Rejected(RejectReason::RateLimited));
            if let Some(log) = &self.wire.audit {
                log.reject(class.name(), RejectReason::RateLimited.name());
            }
            return;
        }
        let (parallelism, device_backlog_us) =
            self.stage
                .gate_inputs(self.placement.as_ref(), group, self.clock.now_us());
        admit_request(
            &mut self.jit,
            &mut self.streams,
            &self.admission,
            &mut self.metrics,
            &self.slots,
            &mut self.wire,
            AdmitReq {
                group,
                tenant,
                arrival_us,
                deadline_us,
                class,
                independent: self.independent,
                parallelism,
                device_backlog_us,
                row,
                token,
            },
        );
    }

    fn issue_and_launch(&mut self) -> Option<f64> {
        let (launches, wake) = self.jit.issue_ready();
        self.view_dirty |= !launches.is_empty();
        for l in launches {
            let group = self
                .jit
                .window
                .get(l.pack.ops[0])
                .map(|op| op.group)
                .unwrap_or(0);
            if let Some(log) = &self.audit {
                // stamp the launch before the stage runs it: an inline
                // stage folds (and retires) the members immediately
                let rows: Vec<(u32, u64, bool)> = l
                    .pack
                    .ops
                    .iter()
                    .filter_map(|id| self.jit.window.get(*id))
                    .map(|op| (op.stream.0, op.seq, op.independent))
                    .collect();
                let class = self
                    .jit
                    .window
                    .get(l.pack.ops[0])
                    .map(|op| op.class.name())
                    .unwrap_or("standard");
                log.launch(l.ticket, group, class, self.jit.pack_cap(group), &rows);
            }
            let now = self.clock.now_us();
            self.stage
                .launch(&mut self.jit, &self.slots, self.placement.as_ref(), group, l, now);
            // inline stages execute in `launch`: fold immediately at the
            // post-execution wall instant (no-op for queued stages —
            // nothing is due at the instant it was launched)
            let done = self
                .stage
                .poll(self.placement.as_ref(), self.clock.now_us(), false);
            self.view_dirty |= !done.is_empty();
            for d in done {
                self.fold(d);
            }
        }
        wake
    }

    /// Fold finished launches, drain the per-launch log, and rebalance.
    fn settle(&mut self, block: bool) {
        let now = self.clock.now_us();
        let done = self.stage.poll(self.placement.as_ref(), now, block);
        self.view_dirty |= !done.is_empty();
        for d in done {
            self.fold(d);
        }
        for l in self.jit.take_launches() {
            if l.ok {
                self.metrics.launch(&l);
            }
        }
        // a variant changed answering tier (first measurement of a
        // warm-started entry, etc.): the memoized per-group estimate
        // tables in the published view are stale even if no completion
        // landed this iteration — force the next snapshot
        let gen = self.jit.executor().estimator_generation();
        if gen != self.last_gen {
            self.last_gen = gen;
            self.view_dirty = true;
        }
        // rebalance between observation windows; keep the estimator's
        // primary device class in step with the table's primaries
        if let Some(p) = self.placement.as_mut() {
            if let Some(rb) = p.rebal.as_mut() {
                let actions = rb.maybe_rebalance(now, &mut p.table, &p.topo);
                if !actions.is_empty() {
                    if let Some(log) = &self.audit {
                        self.audit_epoch += 1;
                        let replicas: Vec<(u64, usize)> = (0..self.slots.len() as u64)
                            .map(|g| (g, p.table.replicas_of(g).len()))
                            .collect();
                        log.rebalance(self.audit_epoch, &replicas);
                    }
                    repin_group_classes(
                        self.jit.executor_mut(),
                        &p.table,
                        &p.topo,
                        self.slots.len() as u64,
                    );
                    // replicas/classes moved: estimates and routing
                    // inputs changed under the last snapshot
                    self.view_dirty = true;
                }
                self.metrics.replications = rb.stats.replications;
                self.metrics.migrations = rb.stats.migrations;
            }
        }
    }

    fn fold(&mut self, d: StageDone) {
        let (ok, dur) = (d.run.ok, d.run.duration_us);
        let completions = self.jit.finish_launch(d.ticket, d.done_us, d.run);
        for c in &completions {
            record_completion(&mut self.metrics, c);
            let token = self.wire.tokens.remove(&c.op.id);
            if let Some(log) = &self.audit {
                log.complete(
                    c.op.stream.0,
                    c.op.seq,
                    c.op.group,
                    c.done_us,
                    c.op.deadline_us,
                    c.met_deadline,
                    c.failed,
                    token.unwrap_or(0),
                );
            }
            if let Some(token) = token {
                let outcome = if c.failed {
                    OpOutcome::Failed
                } else {
                    OpOutcome::Done {
                        latency_us: c.latency_us(),
                        met_deadline: c.met_deadline,
                    }
                };
                self.wire.emit(token, outcome);
            }
        }
        if ok {
            if let Some(p) = self.placement.as_mut() {
                if p.report_devices {
                    self.metrics
                        .device_launch(d.worker, p.topo.spec_of(d.worker).name, dur);
                }
                if let Some(rb) = p.rebal.as_mut() {
                    rb.observe_launch(d.group, d.worker, dur);
                }
            }
        }
    }

    /// Build the full admission snapshot the frontend stage prices
    /// against (one [`frontend::GroupView`] per group, plus the drain
    /// counters that net off the frontend's accept counts).
    fn build_view(&self, seq: u64) -> AdmissionView {
        let now = self.clock.now_us();
        AdmissionView {
            seq,
            now_us: self.jit.now_us,
            published: Instant::now(),
            groups: (0..self.drained.len() as u64)
                .map(|g| {
                    let (par, backlog) =
                        self.stage.gate_inputs(self.placement.as_ref(), g, now);
                    frontend::snapshot_group(&self.jit, g, par, backlog, true)
                })
                .collect(),
            drained: self.drained.clone(),
            drained_by_stream: self.drained_by_stream.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::DeviceSpec;
    use crate::serve::server::{BatchPolicy, SimBackend};

    fn slots() -> Vec<ModelSlot> {
        vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }]
    }

    /// Sync-gate test rig: the JIT plus the gate state `admit_request`
    /// threads through it.
    struct Gate<'b> {
        jit: ServeJit<&'b mut SimBackend>,
        streams: BTreeMap<(u32, u64), u32>,
        admission: Admission,
        metrics: ServeMetrics,
        wire: WireSink,
    }

    impl<'b> Gate<'b> {
        fn new(backend: &'b mut SimBackend, policy: &BatchPolicy) -> Self {
            let slots = slots();
            let cfg = policy.jit_config(&slots, 64);
            Gate {
                jit: JitCompiler::with_payloads(cfg, ServeExecutor::new(backend, slots)),
                streams: BTreeMap::new(),
                admission: Admission::default(),
                metrics: ServeMetrics::default(),
                wire: WireSink::default(),
            }
        }

        fn admit(&mut self, tenant: u32, deadline_us: f64, independent: bool) {
            self.admit_with(tenant, deadline_us, independent, 1.0, None);
        }

        fn admit_with(
            &mut self,
            tenant: u32,
            deadline_us: f64,
            independent: bool,
            parallelism: f64,
            device_backlog_us: Option<f64>,
        ) {
            self.admit_class(
                tenant,
                SloClass::Standard,
                deadline_us,
                independent,
                parallelism,
                device_backlog_us,
            );
        }

        fn admit_class(
            &mut self,
            tenant: u32,
            class: SloClass,
            deadline_us: f64,
            independent: bool,
            parallelism: f64,
            device_backlog_us: Option<f64>,
        ) {
            admit_request(
                &mut self.jit,
                &mut self.streams,
                &self.admission,
                &mut self.metrics,
                &slots(),
                &mut self.wire,
                AdmitReq {
                    group: 0,
                    tenant,
                    arrival_us: 0.0,
                    deadline_us,
                    class,
                    independent,
                    parallelism,
                    device_backlog_us,
                    row: vec![0.0; 4],
                    token: 0,
                },
            );
        }

        fn drops(&self) -> u64 {
            self.metrics.tenants.values().map(|t| t.dropped).sum()
        }
    }

    #[test]
    fn dependent_stream_admission_prices_per_op_drain() {
        // with program order binding a queued stream drains one op per
        // launch — pricing it at the pack cap (one padded batch) would
        // re-open the doomed-admission hole for stateful streams
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::coalescing()); // cap 16
        for _ in 0..4 {
            g.admit(0, 1e9, false);
        }
        assert_eq!(g.jit.window.pending_in_group(0), 4);
        // true drain is 5 singleton launches (2750µs), not one padded
        // batch (900µs): a 1500µs deadline must be shed
        g.admit(0, 1_500.0, false);
        assert_eq!(g.drops(), 1, "doomed dependent request is shed");
    }

    #[test]
    fn dependent_multi_stream_queue_prices_cross_stream_packing() {
        // 8 DISTINCT dependent streams with one op each drain in about one
        // cap-wide launch — admission must not price them as 8 serial
        // launches and shed an easily-servable 9th request
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::coalescing()); // cap 16
        for t in 0..8 {
            g.admit(t, 1e9, false);
        }
        assert_eq!(g.jit.window.pending_in_group(0), 8);
        // all 9 ops are stream heads, so the drain is ONE 9-wide launch
        // (padded 16) ≈ 1300µs — well inside a 2.5ms deadline (a naive
        // one-launch-per-op price of 9·550µs = 4950µs would shed it)
        g.admit(9, 2_500.0, false);
        assert_eq!(g.drops(), 0, "servable multi-stream dependent load admitted");
        assert_eq!(g.jit.window.pending_in_group(0), 9);
    }

    #[test]
    fn admission_prices_inflight_drain() {
        // a request that survives queue-only pricing but is doomed behind
        // the group's in-flight launches must be shed
        let mut backend = SimBackend::default();
        let policy = BatchPolicy::Coalescing {
            window_us: 0.0,
            target_batch: 1,
            safety_margin_us: 0.0,
        };
        let mut g = Gate::new(&mut backend, &policy);
        for t in 0..4 {
            g.admit(t, 1e9, true);
        }
        let (launches, _) = g.jit.issue_ready();
        assert!(!launches.is_empty());
        assert_eq!(g.jit.window.inflight_in_group(0), 4, "work is on the device");
        assert_eq!(g.jit.window.pending_in_group(0), 0);
        // a doomed request into an EMPTY queue still runs, in-flight work
        // notwithstanding (the documented escape hatch)
        g.admit(8, 600.0, true);
        assert_eq!(g.drops(), 0, "empty-queue escape hatch fires despite in-flight");
        assert_eq!(g.jit.window.pending_in_group(0), 1);
        // now real work is queued: a doomed request is shed — queue-only
        // pricing is 600µs but the pending batch-4 launch's scheduler
        // estimate adds 700µs, so a 1000µs deadline is hopeless
        g.admit(9, 1_000.0, true);
        assert_eq!(g.drops(), 1, "doomed request behind in-flight work is shed");
        assert_eq!(g.jit.window.pending_in_group(0), 1, "it was never submitted");
        // enough slack to survive the full (queue + in-flight) drain
        g.admit(10, 2_000.0, true);
        assert_eq!(g.jit.window.pending_in_group(0), 2);
        assert_eq!(g.drops(), 1, "no new drop");
    }

    #[test]
    fn admission_prices_each_inflight_launch_separately() {
        // 4 singleton launches drain in 4·550µs = 2200µs, NOT the 700µs
        // one batch-4 launch would take
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::NoBatching);
        for t in 0..4 {
            g.admit(t, 1e9, true);
        }
        let (launches, _) = g.jit.issue_ready();
        assert_eq!(launches.len(), 4, "NoBatching issues singletons");
        assert!((g.jit.inflight_group_est_us(0, 1) - 2_200.0).abs() < 1e-9);
        // queue one request with slack to spare so the doomed-shed hatch
        // applies to what follows
        g.admit(8, 1e9, true);
        assert_eq!(g.jit.window.pending_in_group(0), 1);
        // deadline 2500µs would survive one-batch in-flight pricing (700
        // + 1100 queue) but not the true per-launch drain (2200 + 1100)
        g.admit(9, 2_500.0, true);
        assert_eq!(g.drops(), 1, "doomed behind four singleton launches");
        // a deadline past the full per-launch drain is still admitted
        g.admit(10, 4_000.0, true);
        assert_eq!(g.jit.window.pending_in_group(0), 2);
    }

    #[test]
    fn admission_prices_queue_deeper_than_one_pack_per_launch() {
        // under NoBatching (pack cap 1), 4 queued singletons + this
        // request cost 5·550µs = 2750µs, not one padded batch's 900µs
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::NoBatching);
        for t in 0..4 {
            g.admit(t, 1e9, true);
        }
        assert_eq!(g.jit.window.pending_in_group(0), 4);
        assert_eq!(g.jit.window.inflight_in_group(0), 0);
        g.admit(9, 1_500.0, true);
        assert_eq!(g.drops(), 1, "doomed behind a deep singleton queue");
        g.admit(10, 3_000.0, true);
        assert_eq!(g.jit.window.pending_in_group(0), 5);
    }

    #[test]
    fn admission_divides_drain_across_replicas() {
        // 4 queued singletons at NoBatching drain in 5 launches = 2750µs
        // on one worker; on two replicas the same queue is priced at half
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::NoBatching);
        for t in 0..4 {
            g.admit(t, 1e9, true);
        }
        assert_eq!(g.jit.window.pending_in_group(0), 4);
        // two replicas: drain 2750/2 = 1375µs < 1500µs deadline → admit
        g.admit_with(9, 1_500.0, true, 2.0, None);
        assert_eq!(g.drops(), 0, "two-replica drain fits the deadline");
        assert_eq!(g.jit.window.pending_in_group(0), 5);
        // heterogeneous replicas are speed-weighted, not counted: a v100
        // primary plus a k80 replica is ~1.25 workers — the queue of 6
        // drains in 6·550/1.25 = 2640µs, so the same 1500µs deadline must
        // be shed
        g.admit_with(10, 1_500.0, true, 1.25, None);
        assert_eq!(g.drops(), 1, "slow replica must not count as a full worker");
        assert_eq!(g.jit.window.pending_in_group(0), 5);
    }

    #[test]
    fn sync_gate_decides_per_class_and_counts_decisions() {
        // the same doomed deadline (negative slack into an empty queue)
        // is a best-effort shed but a latency-class accept — and both
        // decisions land in the per-class decision counters
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::coalescing());
        g.admit_class(0, SloClass::BestEffort, 10.0, true, 1.0, None);
        assert_eq!(g.drops(), 1, "doomed best-effort has no escape hatch");
        assert_eq!(g.jit.window.pending_in_group(0), 0);
        g.admit_class(1, SloClass::Critical, 10.0, true, 1.0, None);
        assert_eq!(g.drops(), 1, "critical keeps the empty-queue hatch");
        assert_eq!(g.jit.window.pending_in_group(0), 1);
        let be = g.metrics.class_metrics(SloClass::BestEffort);
        assert_eq!((be.accepts, be.rejects), (0, 1));
        let crit = g.metrics.class_metrics(SloClass::Critical);
        assert_eq!((crit.accepts, crit.rejects), (1, 0));
    }

    #[test]
    fn submitted_request_carries_its_class_into_the_window() {
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::coalescing());
        g.admit_class(0, SloClass::Critical, 1e9, true, 1.0, None);
        let ready = g.jit.window.ready();
        let op = ready.first().expect("submitted op");
        assert_eq!(op.class, SloClass::Critical);
    }

    fn placement_on(topo: DeviceTopology, groups: u64) -> Placement {
        let costs: Vec<(u64, f64)> = (0..groups).map(|g| (g, 1.0)).collect();
        let table = Placer::place(&costs, &topo);
        Placement {
            topo,
            table,
            rebal: None,
            report_devices: false,
        }
    }

    #[test]
    fn pooled_paths_agree_on_admission_inputs() {
        // on a single-worker fleet the placement-routed and legacy
        // hash-routed launch stages must feed the gate identical
        // (parallelism, backlog) inputs — so the two paths admit
        // identically on the same trace
        let placed = placement_on(DeviceTopology::homogeneous(1, DeviceSpec::v100()), 3);
        let backlog = vec![1_234.0];
        for g in 0..3u64 {
            assert_eq!(
                pool_gate_inputs(Some(&placed), 1, &backlog, g),
                pool_gate_inputs(None, 1, &backlog, g),
                "group {g}"
            );
        }
    }

    #[test]
    fn unplaced_pooled_backlog_feeds_the_gate() {
        // the legacy hash-routed pool books est_routed into
        // worker_backlog at launch, so admission must consult the
        // hash-routed worker's entry instead of flying queue-blind
        let backlog = vec![5_000.0, 0.0];
        assert_eq!(pool_gate_inputs(None, 2, &backlog, 0), (1.0, Some(5_000.0)));
        assert_eq!(pool_gate_inputs(None, 2, &backlog, 1), (1.0, Some(0.0)));
        assert_eq!(pool_gate_inputs(None, 2, &backlog, 2), (1.0, Some(5_000.0)));
        // no pool at all: nothing measured, the JIT in-flight term prices
        assert_eq!(pool_gate_inputs(None, 0, &backlog, 0), (1.0, None));

        // and the booked backlog actually reaches the shed decision: 5ms
        // on the routed worker dooms a 2ms deadline that the same gate
        // admits when the worker is free
        let mut backend = SimBackend::default();
        let mut g = Gate::new(&mut backend, &BatchPolicy::coalescing());
        for (tenant, deadline, booked) in
            [(0u32, 1e9, 0.0), (1, 2_000.0, 5_000.0), (2, 2_000.0, 0.0)]
        {
            let (parallelism, backlog) = pool_gate_inputs(None, 2, &[booked, 0.0], 0);
            g.admit_with(tenant, deadline, true, parallelism, backlog);
        }
        assert_eq!(
            g.metrics.tenants.get(&1).map(|t| t.dropped),
            Some(1),
            "booked backlog must shed the doomed request"
        );
        assert_eq!(g.jit.window.pending_in_group(0), 2, "tenants 0 and 2 admitted");
    }

    #[test]
    fn timeline_pops_completions_in_done_then_ticket_order() {
        // the BinaryHeap must reproduce the old sort-by-(done, ticket)
        // fold order exactly — virtual-replay determinism hangs on it
        let mut stage = TimelineStage::new(2);
        let mk = |done_us: f64, ticket: u64| {
            Reverse(TimelineEntry {
                done_us,
                ticket,
                worker: 0,
                group: 0,
                run: PackRun {
                    duration_us: 1.0,
                    executed: 1,
                    ok: true,
                    device_class: 0,
                },
            })
        };
        for (d, t) in [(30.0, 4), (10.0, 2), (10.0, 1), (20.0, 3), (5.0, 0)] {
            stage.inflight.push(mk(d, t));
        }
        assert_eq!(
            <TimelineStage as LaunchStage<SimBackend>>::next_done_us(&stage),
            Some(5.0)
        );
        let due =
            <TimelineStage as LaunchStage<SimBackend>>::poll(&mut stage, None, 10.0, false);
        let order: Vec<(f64, u64)> = due.iter().map(|d| (d.done_us, d.ticket)).collect();
        assert_eq!(order, vec![(5.0, 0), (10.0, 1), (10.0, 2)]);
        // the rest stay queued for the next advance
        assert_eq!(
            <TimelineStage as LaunchStage<SimBackend>>::next_done_us(&stage),
            Some(20.0)
        );
    }

    #[test]
    fn timeline_gate_inputs_price_the_device_queue() {
        let mut stage = TimelineStage::new(1);
        stage.free_at[0] = 4_000.0;
        let (par, backlog) =
            <TimelineStage as LaunchStage<SimBackend>>::gate_inputs(&stage, None, 0, 1_000.0);
        assert_eq!(par, 1.0);
        assert_eq!(backlog, Some(3_000.0), "queued device time ahead of now");
        // a free device owes nothing (clamped at zero)
        let (_, b2) =
            <TimelineStage as LaunchStage<SimBackend>>::gate_inputs(&stage, None, 0, 9_000.0);
        assert_eq!(b2, Some(0.0));
    }
}
