//! Serving metrics: per-tenant latency distributions, SLO attainment,
//! batch occupancy, device-busy accounting, and the JIT core's per-launch
//! pack statistics (mean pack, padding efficiency, evictions).
//!
//! Since the SLO-class refactor every attainment/admission/latency
//! counter is also decomposed per [`SloClass`] ([`ServeMetrics::classes`],
//! indexed by [`SloClass::index`]) — the per-class numbers are what the
//! `slo-mix` bench asserts on (critical attainment must survive a
//! saturating best-effort tenant).

use std::collections::BTreeMap;

use crate::compiler::ir::SloClass;
use crate::compiler::jit::{JitStats, LaunchRecord};
use crate::estimate::EstimatorStats;
use crate::serve::frontend::{FrontendReport, RejectReason};
use crate::util::stats::LatencyHist;

/// Per-shard accounting of the socket intake pool — how much wire work
/// one shard worker forwarded and how many connections it owned at peak
/// (the per-shard depth signal for deciding when to grow the pool).
#[derive(Debug, Clone, Default)]
pub struct IntakeShardMetrics {
    /// Wire ops this shard forwarded into the engine's intake channel.
    pub forwarded: u64,
    /// Peak simultaneous connections owned by this shard.
    pub peak_conns: u64,
}

/// The socket intake subsystem's accounting, rendered with the serve
/// report and emitted in the wire bench JSON. Populated only by wire
/// runs (`vliwd serve --listen`, `vliwd bench --wire`); all-zero — and
/// unrendered — for trace-driven runs.
#[derive(Debug, Clone, Default)]
pub struct IntakeMetrics {
    /// Frame decode time (header + JSON payload → request), µs.
    pub decode: LatencyHist,
    /// Wire accept latency: frame fully read → every op of the request
    /// forwarded into the engine's intake channel, µs.
    pub accept_latency: LatencyHist,
    /// Connections accepted over the run.
    pub connections: u64,
    /// Connections that closed (client EOF, protocol error, shutdown).
    pub disconnects: u64,
    /// Histogram of client batch sizes (ops per wire request).
    pub batch_sizes: BTreeMap<u32, u64>,
    /// Per-shard depth/forwarding accounting, indexed by shard id.
    pub shards: Vec<IntakeShardMetrics>,
    /// Replies written back to clients.
    pub replies: u64,
    /// Replies dropped because the client was gone at write time.
    pub dropped_replies: u64,
    /// Completion events whose batch was already purged (client
    /// disconnected mid-flight) — bounded bookkeeping, not a leak.
    pub orphan_events: u64,
}

impl IntakeMetrics {
    /// Wire requests decoded (one per client batch).
    pub fn requests(&self) -> u64 {
        self.batch_sizes.values().sum()
    }

    /// Mean client batch size (ops per wire request).
    pub fn mean_batch(&self) -> f64 {
        let reqs = self.requests();
        if reqs == 0 {
            0.0
        } else {
            let ops: u64 = self.batch_sizes.iter().map(|(b, n)| *b as u64 * n).sum();
            ops as f64 / reqs as f64
        }
    }
}

/// Metrics for one tenant.
#[derive(Debug, Clone, Default)]
pub struct TenantMetrics {
    /// Latency distribution (µs).
    pub latency: LatencyHist,
    /// Requests meeting their deadline.
    pub slo_hits: u64,
    /// Requests missing their deadline.
    pub slo_misses: u64,
    /// Requests dropped by admission control.
    pub dropped: u64,
}

impl TenantMetrics {
    /// SLO attainment in [0,1] (dropped requests count as misses).
    pub fn attainment(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses + self.dropped;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }

    /// Completed request count.
    pub fn completed(&self) -> u64 {
        self.slo_hits + self.slo_misses
    }
}

/// Metrics for one SLO class — the same attainment contract as
/// [`TenantMetrics`] plus the gate-decision counters the class contract
/// hangs on (how much of a class was admitted, shed, or rate-shaped).
#[derive(Debug, Clone, Default)]
pub struct ClassMetrics {
    /// Latency distribution of completed requests, µs.
    pub latency: LatencyHist,
    /// Requests meeting their deadline.
    pub slo_hits: u64,
    /// Requests missing their deadline.
    pub slo_misses: u64,
    /// Requests dropped (gate rejects, window sheds, failed executions).
    pub dropped: u64,
    /// Admission-gate accepts.
    pub accepts: u64,
    /// Admission-gate rejects (shaped requests included).
    pub rejects: u64,
    /// Requests rejected by the per-tenant token bucket *before* pricing
    /// (a subset of `rejects`).
    pub shaped: u64,
}

impl ClassMetrics {
    /// SLO attainment in [0,1] (dropped requests count as misses).
    pub fn attainment(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses + self.dropped;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }

    /// Completed request count.
    pub fn completed(&self) -> u64 {
        self.slo_hits + self.slo_misses
    }

    /// Gate decisions recorded against this class.
    pub fn decisions(&self) -> u64 {
        self.accepts + self.rejects
    }
}

/// Per-device accounting for placed (multi-device) runs: which worker
/// executed how much. Indexed by pool-worker id in
/// [`ServeMetrics::devices`]; empty for single-device drive modes.
#[derive(Debug, Clone, Default)]
pub struct DeviceMetrics {
    /// Device spec name backing the worker ("v100", ...).
    pub name: String,
    /// Launches executed on this worker.
    pub launches: u64,
    /// Busy time on this worker, µs.
    pub busy_us: f64,
}

impl DeviceMetrics {
    /// Fraction of the run's span this worker was busy.
    pub fn utilization(&self, span_us: f64) -> f64 {
        if span_us <= 0.0 {
            0.0
        } else {
            (self.busy_us / span_us).min(1.0)
        }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Per-tenant metrics.
    pub tenants: BTreeMap<u32, TenantMetrics>,
    /// Per-class metrics, indexed by [`SloClass::index`].
    pub classes: [ClassMetrics; 3],
    /// Histogram of executed batch occupancy (real rows, not padding).
    pub batch_occupancy: BTreeMap<u32, u64>,
    /// Executed batches.
    pub batches: u64,
    /// Total rows executed (incl. padding).
    pub padded_rows: u64,
    /// Total useful rows executed.
    pub useful_rows: u64,
    /// Rows that shared a launch with an earlier row of the same (tenant,
    /// model) stream — the stream-prefix coalescing a single tenant's
    /// burst now gets (0 under one-request-per-stream packing). Counts
    /// *executed* (ok) launches only, consistent with `batches` /
    /// `useful_rows`; the JIT-level count over all launches including
    /// failed ones is `jit.same_stream_rows` (they differ exactly when a
    /// backend execution failed).
    pub same_stream_rows: u64,
    /// Device busy time, µs.
    pub busy_us: f64,
    /// Wall/virtual span of the run, µs.
    pub span_us: f64,
    /// The JIT core's aggregate stats for the run (launches, mean pack,
    /// pack efficiency, evictions) — the serving layer and the scheduler
    /// share one core, so these are the same numbers the benches report.
    pub jit: JitStats,
    /// Per-worker device accounting (placed runs; empty otherwise).
    pub devices: Vec<DeviceMetrics>,
    /// Hot-group replications applied by the rebalancer.
    pub replications: u64,
    /// Cold-group migrations applied by the rebalancer.
    pub migrations: u64,
    /// Admission-decision latency (client arrival → gate decision), µs.
    /// With the frontend stage this stays bounded regardless of engine
    /// stalls; the synchronous wall-clock gate includes the drain wait.
    /// Empty for the virtual-time replays (no wall clock to measure).
    pub admission_latency: LatencyHist,
    /// Channel wait (client arrival → engine submit), µs — the time a
    /// request sat between threads before being priced into the window,
    /// previously invisible in SLO decompositions. Covers every request
    /// that *reaches the engine thread*: all arrivals on the
    /// synchronous path (the decision happens at drain), accepted
    /// requests on the frontend path (rejects turn around at the
    /// frontend and never cross). Empty for the virtual-time replays.
    pub frontend_wait: LatencyHist,
    /// Admission decisions recorded in `admission_latency`.
    pub admission_decisions: u64,
    /// Frontend decisions taken on a snapshot older than
    /// [`crate::serve::frontend::STALE_VIEW_US`] (scheduler wedged
    /// mid-iteration while the frontend kept answering).
    pub stale_decisions: u64,
    /// The run's estimator accounting: which tier (Measured / Tuned /
    /// Prior) answered each duration query, and the |predicted − actual|
    /// launch-duration error histogram — see [`crate::estimate`].
    pub estimator: EstimatorStats,
    /// Sheds decomposed by *why*, per class:
    /// `rejects_by_reason[reason.index()][class.index()]`. Counted at
    /// the engine when it receives a `FromFrontend::Rejected` record (or
    /// sheds synchronously itself), so a wire client's "rejected" reply
    /// and these counters tell the same story.
    pub rejects_by_reason: [[u64; 3]; 3],
    /// The socket intake subsystem's accounting (wire runs only).
    pub intake: IntakeMetrics,
}

impl ServeMetrics {
    /// Record one completed request against its tenant and class.
    pub fn complete(&mut self, tenant: u32, class: SloClass, latency_us: f64, met: bool) {
        let t = self.tenants.entry(tenant).or_default();
        t.latency.record_us(latency_us);
        let c = &mut self.classes[class.index()];
        c.latency.record_us(latency_us);
        if met {
            t.slo_hits += 1;
            c.slo_hits += 1;
        } else {
            t.slo_misses += 1;
            c.slo_misses += 1;
        }
    }

    /// Record a dropped request against its tenant and class.
    pub fn drop_request(&mut self, tenant: u32, class: SloClass) {
        self.tenants.entry(tenant).or_default().dropped += 1;
        self.classes[class.index()].dropped += 1;
    }

    /// Record a request the per-tenant token bucket rejected before
    /// pricing: a drop, a gate reject, and a shaped count all at once.
    pub fn shaped_request(&mut self, tenant: u32, class: SloClass) {
        self.drop_request(tenant, class);
        let c = &mut self.classes[class.index()];
        c.rejects += 1;
        c.shaped += 1;
    }

    /// Record *why* a request was shed, against its class. Orthogonal to
    /// the drop/reject counters (those say *how many*, this says *why*),
    /// so callers record both.
    pub fn reject_reason(&mut self, reason: RejectReason, class: SloClass) {
        self.rejects_by_reason[reason.index()][class.index()] += 1;
    }

    /// Total sheds recorded with a reason.
    pub fn reason_total(&self) -> u64 {
        self.rejects_by_reason.iter().flatten().sum()
    }

    /// Record one admission-gate decision against its class.
    pub fn gate_decision(&mut self, class: SloClass, accepted: bool) {
        let c = &mut self.classes[class.index()];
        if accepted {
            c.accepts += 1;
        } else {
            c.rejects += 1;
        }
    }

    /// One class's metrics.
    pub fn class_metrics(&self, class: SloClass) -> &ClassMetrics {
        &self.classes[class.index()]
    }

    /// One class's SLO attainment (1.0 when the class saw no traffic).
    pub fn class_attainment(&self, class: SloClass) -> f64 {
        self.classes[class.index()].attainment()
    }

    /// One class's goodput in requests/s over the span.
    pub fn class_throughput(&self, class: SloClass) -> f64 {
        if self.span_us <= 0.0 {
            0.0
        } else {
            self.classes[class.index()].completed() as f64 / (self.span_us / 1e6)
        }
    }

    /// Record one executed batch (useful rows, padded variant size, µs).
    pub fn batch(&mut self, useful: u32, padded: u32, dur_us: f64) {
        *self.batch_occupancy.entry(useful).or_default() += 1;
        self.batches += 1;
        self.useful_rows += useful as u64;
        self.padded_rows += padded as u64;
        self.busy_us += dur_us;
    }

    /// Record one executed launch from the JIT's per-launch log (batch
    /// accounting plus the launch's same-stream row count).
    pub fn launch(&mut self, l: &LaunchRecord) {
        self.batch(l.pack_size, l.executed, l.duration_us);
        self.same_stream_rows += l.same_stream_rows as u64;
    }

    /// Register a fleet worker so placed runs report every device, busy
    /// or idle (BENCH per-device utilization must show the idle t4 too).
    pub fn ensure_device(&mut self, worker: usize, name: &str) {
        while self.devices.len() <= worker {
            self.devices.push(DeviceMetrics::default());
        }
        if self.devices[worker].name.is_empty() {
            self.devices[worker].name = name.to_string();
        }
    }

    /// Record one executed launch against the worker that ran it.
    pub fn device_launch(&mut self, worker: usize, name: &str, duration_us: f64) {
        self.ensure_device(worker, name);
        let d = &mut self.devices[worker];
        d.launches += 1;
        d.busy_us += duration_us;
    }

    /// Fold the frontend stage's thread-local accounting into the run's
    /// metrics (called once by the scheduler thread after joining the
    /// frontend).
    pub fn merge_frontend(&mut self, rep: &FrontendReport) {
        for (tenant, n) in &rep.drops {
            self.tenants.entry(*tenant).or_default().dropped += n;
        }
        for class in SloClass::ALL {
            let i = class.index();
            let c = &mut self.classes[i];
            c.accepts += rep.accepts_by_class[i];
            c.rejects += rep.rejects_by_class[i];
            c.shaped += rep.shaped_by_class[i];
            // a frontend reject never reaches the engine: it is this
            // class's drop as well as its reject
            c.dropped += rep.rejects_by_class[i];
        }
        self.admission_latency.merge(&rep.admission_latency);
        self.admission_decisions += rep.decisions;
        self.stale_decisions += rep.stale_decisions;
    }

    /// Record a synchronous-gate admission decision's latency (arrival →
    /// decision; the decision and the submit coincide on that path).
    pub fn sync_admission_decision(&mut self, wait_us: f64) {
        self.admission_latency.record_us(wait_us);
        self.frontend_wait.record_us(wait_us);
        self.admission_decisions += 1;
    }

    /// Completed requests across tenants.
    pub fn total_completed(&self) -> u64 {
        self.tenants.values().map(|t| t.completed()).sum()
    }

    /// Overall SLO attainment.
    pub fn overall_attainment(&self) -> f64 {
        let hits: u64 = self.tenants.values().map(|t| t.slo_hits).sum();
        let total: u64 = self
            .tenants
            .values()
            .map(|t| t.slo_hits + t.slo_misses + t.dropped)
            .sum();
        if total == 0 {
            1.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Goodput in requests/s over the span.
    pub fn throughput(&self) -> f64 {
        if self.span_us <= 0.0 {
            0.0
        } else {
            self.total_completed() as f64 / (self.span_us / 1e6)
        }
    }

    /// Mean executed batch occupancy.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.useful_rows as f64 / self.batches as f64
        }
    }

    /// Padding efficiency (useful / executed rows).
    pub fn row_efficiency(&self) -> f64 {
        if self.padded_rows == 0 {
            1.0
        } else {
            self.useful_rows as f64 / self.padded_rows as f64
        }
    }

    /// Device duty cycle over the span.
    pub fn duty_cycle(&self) -> f64 {
        if self.span_us <= 0.0 {
            0.0
        } else {
            (self.busy_us / self.span_us).min(1.0)
        }
    }

    /// Human-readable report table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests={} batches={} mean_occ={:.2} same_stream={} row_eff={:.2} duty={:.2} thpt={:.1}/s attain={:.3}\n",
            self.total_completed(),
            self.batches,
            self.mean_occupancy(),
            self.same_stream_rows,
            self.row_efficiency(),
            self.duty_cycle(),
            self.throughput(),
            self.overall_attainment(),
        ));
        if self.jit.launches > 0 {
            s.push_str(&format!(
                "jit: launches={} mean_pack={:.2} pack_eff={:.2} evictions={} slo_attain={:.3}\n",
                self.jit.launches,
                self.jit.mean_pack(),
                self.jit.pack_efficiency(),
                self.jit.evictions,
                self.jit.slo_attainment(),
            ));
        }
        if self.jit.decide_ns.count() > 0 {
            s.push_str(&format!(
                "scheduler: decides={} decide_p50={}ns decide_p99={}ns buckets_reused={} buckets_repacked={}\n",
                self.jit.decide_ns.count(),
                self.jit.decide_ns.quantile_us(0.5) as u64,
                self.jit.decide_ns.quantile_us(0.99) as u64,
                self.jit.buckets_reused,
                self.jit.buckets_repacked,
            ));
        }
        if self.estimator.total_hits() > 0 {
            s.push_str(&format!(
                "estimator: measured={} tuned={} prior={} err_p50={:.1}us err_p99={:.1}us\n",
                self.estimator.measured_hits,
                self.estimator.tuned_hits,
                self.estimator.prior_hits,
                self.estimator.est_err.quantile_us(0.5),
                self.estimator.est_err.quantile_us(0.99),
            ));
        }
        if self.admission_decisions > 0 {
            s.push_str(&format!(
                "admission: decisions={} p99={:.2}ms stale={} frontend_wait_p99={:.2}ms\n",
                self.admission_decisions,
                self.admission_latency.quantile_us(0.99) / 1e3,
                self.stale_decisions,
                self.frontend_wait.quantile_us(0.99) / 1e3,
            ));
        }
        if self.reason_total() > 0 {
            s.push_str("shed:");
            for reason in RejectReason::ALL {
                let by_class = &self.rejects_by_reason[reason.index()];
                let total: u64 = by_class.iter().sum();
                if total == 0 {
                    continue;
                }
                s.push_str(&format!(
                    " {}={} (crit={} std={} be={})",
                    reason.name(),
                    total,
                    by_class[SloClass::Critical.index()],
                    by_class[SloClass::Standard.index()],
                    by_class[SloClass::BestEffort.index()],
                ));
            }
            s.push('\n');
        }
        if self.intake.connections > 0 {
            let i = &self.intake;
            s.push_str(&format!(
                "intake: conns={} disconnects={} requests={} mean_batch={:.2} decode_p99={:.1}us accept_p99={:.2}ms replies={} dropped={} orphans={}\n",
                i.connections,
                i.disconnects,
                i.requests(),
                i.mean_batch(),
                i.decode.quantile_us(0.99),
                i.accept_latency.quantile_us(0.99) / 1e3,
                i.replies,
                i.dropped_replies,
                i.orphan_events,
            ));
            for (n, sh) in i.shards.iter().enumerate() {
                s.push_str(&format!(
                    "intake shard {n}: forwarded={} peak_conns={}\n",
                    sh.forwarded, sh.peak_conns
                ));
            }
        }
        if !self.devices.is_empty() {
            s.push_str(&format!(
                "placement: replications={} migrations={}\n",
                self.replications, self.migrations
            ));
            for (w, d) in self.devices.iter().enumerate() {
                s.push_str(&format!(
                    "device {w} ({}): launches={} busy={:.1}ms util={:.2}\n",
                    d.name,
                    d.launches,
                    d.busy_us / 1e3,
                    d.utilization(self.span_us),
                ));
            }
        }
        if self.classes.iter().any(|c| c.completed() + c.dropped + c.decisions() > 0) {
            s.push_str("class        n     p50(ms)  p99(ms)  attain  drops  shaped\n");
            for class in SloClass::ALL {
                let c = &self.classes[class.index()];
                if c.completed() + c.dropped + c.decisions() == 0 {
                    continue;
                }
                s.push_str(&format!(
                    "{:<11} {:<6} {:<8.2} {:<8.2} {:<7.3} {:<6} {}\n",
                    class.name(),
                    c.completed(),
                    c.latency.quantile_us(0.5) / 1e3,
                    c.latency.quantile_us(0.99) / 1e3,
                    c.attainment(),
                    c.dropped,
                    c.shaped,
                ));
            }
        }
        s.push_str("tenant     n     p50(ms)  p99(ms)  max(ms)  attain  drops\n");
        for (id, t) in &self.tenants {
            s.push_str(&format!(
                "{:<8} {:<6} {:<8.2} {:<8.2} {:<8.2} {:<7.3} {}\n",
                id,
                t.completed(),
                t.latency.quantile_us(0.5) / 1e3,
                t.latency.quantile_us(0.99) / 1e3,
                t.latency.max_us() / 1e3,
                t.attainment(),
                t.dropped,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainment_counts_drops_as_misses() {
        let mut m = ServeMetrics::default();
        m.complete(0, SloClass::Standard, 1000.0, true);
        m.complete(0, SloClass::Standard, 1000.0, true);
        m.drop_request(0, SloClass::Standard);
        let t = &m.tenants[&0];
        assert!((t.attainment() - 2.0 / 3.0).abs() < 1e-9);
        assert!((m.overall_attainment() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn batch_accounting() {
        let mut m = ServeMetrics::default();
        m.batch(3, 4, 100.0);
        m.batch(1, 1, 50.0);
        assert_eq!(m.batches, 2);
        assert_eq!(m.mean_occupancy(), 2.0);
        assert!((m.row_efficiency() - 4.0 / 5.0).abs() < 1e-9);
        assert_eq!(m.batch_occupancy[&3], 1);
    }

    #[test]
    fn launch_records_same_stream_rows() {
        let mut m = ServeMetrics::default();
        m.launch(&LaunchRecord {
            pack_size: 4,
            executed: 4,
            duration_us: 100.0,
            ok: true,
            same_stream_rows: 3,
        });
        m.launch(&LaunchRecord {
            pack_size: 2,
            executed: 2,
            duration_us: 50.0,
            ok: true,
            same_stream_rows: 0,
        });
        assert_eq!(m.batches, 2);
        assert_eq!(m.useful_rows, 6);
        assert_eq!(m.same_stream_rows, 3);
        assert!(m.render().contains("same_stream=3"));
    }

    #[test]
    fn device_accounting_and_render() {
        let mut m = ServeMetrics::default();
        m.ensure_device(0, "v100");
        m.ensure_device(1, "t4");
        m.device_launch(0, "v100", 400_000.0);
        m.device_launch(0, "v100", 100_000.0);
        m.span_us = 1_000_000.0;
        m.replications = 1;
        assert_eq!(m.devices.len(), 2);
        assert_eq!(m.devices[0].launches, 2);
        assert!((m.devices[0].utilization(m.span_us) - 0.5).abs() < 1e-9);
        assert_eq!(m.devices[1].launches, 0, "idle device still reported");
        assert_eq!(m.devices[1].name, "t4");
        let r = m.render();
        assert!(r.contains("device 0 (v100)"), "{r}");
        assert!(r.contains("device 1 (t4)"), "{r}");
        assert!(r.contains("replications=1"), "{r}");
    }

    #[test]
    fn render_omits_devices_for_single_device_runs() {
        let mut m = ServeMetrics::default();
        m.complete(0, SloClass::Standard, 1_000.0, true);
        m.span_us = 1e6;
        assert!(!m.render().contains("device 0"));
        assert!(!m.render().contains("placement:"));
    }

    #[test]
    fn throughput_and_duty() {
        let mut m = ServeMetrics::default();
        for _ in 0..10 {
            m.complete(1, SloClass::Standard, 500.0, true);
        }
        m.busy_us = 400_000.0;
        m.span_us = 1_000_000.0;
        assert!((m.throughput() - 10.0).abs() < 1e-9);
        assert!((m.duty_cycle() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn render_contains_tenants() {
        let mut m = ServeMetrics::default();
        m.complete(7, SloClass::Standard, 2_000.0, false);
        m.span_us = 1e6;
        let r = m.render();
        assert!(r.contains("tenant"));
        assert!(r.contains('7'));
    }

    #[test]
    fn frontend_report_merges_and_renders() {
        let mut m = ServeMetrics::default();
        assert!(!m.render().contains("admission:"), "no line before decisions");
        m.span_us = 1e6;
        let mut rep = FrontendReport {
            decisions: 5,
            stale_decisions: 2,
            ..Default::default()
        };
        rep.admission_latency.record_us(120.0);
        rep.drops.insert(3, 2);
        m.merge_frontend(&rep);
        m.sync_admission_decision(80.0);
        assert_eq!(m.admission_decisions, 6);
        assert_eq!(m.stale_decisions, 2);
        assert_eq!(m.tenants[&3].dropped, 2);
        assert_eq!(m.admission_latency.count(), 2);
        assert_eq!(m.frontend_wait.count(), 1);
        let r = m.render();
        assert!(r.contains("admission: decisions=6"), "{r}");
        assert!(r.contains("stale=2"), "{r}");
    }

    #[test]
    fn class_decomposition_tracks_complete_drop_and_shape() {
        let mut m = ServeMetrics::default();
        m.complete(0, SloClass::Critical, 1_000.0, true);
        m.complete(1, SloClass::Critical, 2_000.0, false);
        m.drop_request(2, SloClass::BestEffort);
        m.shaped_request(2, SloClass::BestEffort);
        m.gate_decision(SloClass::Critical, true);
        m.span_us = 1e6;
        let crit = m.class_metrics(SloClass::Critical);
        assert_eq!(crit.completed(), 2);
        assert_eq!(crit.accepts, 1);
        assert!((m.class_attainment(SloClass::Critical) - 0.5).abs() < 1e-9);
        let be = m.class_metrics(SloClass::BestEffort);
        assert_eq!(be.dropped, 2, "shaped requests are drops too");
        assert_eq!(be.shaped, 1);
        assert_eq!(be.rejects, 1);
        assert_eq!(m.class_attainment(SloClass::Standard), 1.0, "idle class");
        assert!((m.class_throughput(SloClass::Critical) - 2.0).abs() < 1e-9);
        let r = m.render();
        assert!(r.contains("critical"), "{r}");
        assert!(r.contains("best_effort"), "{r}");
        assert!(!r.contains("standard"), "idle class stays out of the table: {r}");
    }

    #[test]
    fn merge_frontend_folds_class_counters() {
        let mut m = ServeMetrics::default();
        let mut rep = FrontendReport::default();
        rep.accepts_by_class[SloClass::Critical.index()] = 3;
        rep.rejects_by_class[SloClass::BestEffort.index()] = 2;
        rep.shaped_by_class[SloClass::BestEffort.index()] = 1;
        m.merge_frontend(&rep);
        assert_eq!(m.class_metrics(SloClass::Critical).accepts, 3);
        let be = m.class_metrics(SloClass::BestEffort);
        assert_eq!(be.rejects, 2);
        assert_eq!(be.dropped, 2, "frontend rejects never reach the engine");
        assert_eq!(be.shaped, 1);
    }

    #[test]
    fn reject_reasons_decompose_per_class_and_render() {
        let mut m = ServeMetrics::default();
        m.span_us = 1e6;
        assert!(!m.render().contains("shed:"), "no line before sheds");
        m.reject_reason(RejectReason::QueueFull, SloClass::Standard);
        m.reject_reason(RejectReason::QueueFull, SloClass::Standard);
        m.reject_reason(RejectReason::RateLimited, SloClass::Critical);
        m.reject_reason(RejectReason::StaleShed, SloClass::BestEffort);
        assert_eq!(m.reason_total(), 4);
        assert_eq!(
            m.rejects_by_reason[RejectReason::QueueFull.index()]
                [SloClass::Standard.index()],
            2
        );
        let r = m.render();
        assert!(r.contains("queue_full=2"), "{r}");
        assert!(r.contains("rate_limited=1 (crit=1 std=0 be=0)"), "{r}");
        assert!(r.contains("stale_shed=1"), "{r}");
    }

    #[test]
    fn intake_metrics_aggregate_and_render() {
        let mut m = ServeMetrics::default();
        m.span_us = 1e6;
        assert!(!m.render().contains("intake:"), "no line before wire traffic");
        m.intake.connections = 3;
        m.intake.disconnects = 1;
        *m.intake.batch_sizes.entry(8).or_default() += 2;
        *m.intake.batch_sizes.entry(1).or_default() += 2;
        m.intake.decode.record_us(12.0);
        m.intake.accept_latency.record_us(90.0);
        m.intake.replies = 4;
        m.intake.shards = vec![
            IntakeShardMetrics { forwarded: 10, peak_conns: 2 },
            IntakeShardMetrics { forwarded: 8, peak_conns: 1 },
        ];
        assert_eq!(m.intake.requests(), 4);
        assert!((m.intake.mean_batch() - 4.5).abs() < 1e-9);
        let r = m.render();
        assert!(r.contains("intake: conns=3"), "{r}");
        assert!(r.contains("mean_batch=4.50"), "{r}");
        assert!(r.contains("intake shard 0: forwarded=10 peak_conns=2"), "{r}");
        assert!(r.contains("intake shard 1: forwarded=8"), "{r}");
    }

    #[test]
    fn render_shows_estimator_tier_hits_when_present() {
        let mut m = ServeMetrics::default();
        m.complete(0, SloClass::Standard, 1_000.0, true);
        m.span_us = 1e6;
        assert!(!m.render().contains("estimator:"), "no line before hits");
        m.estimator.measured_hits = 5;
        m.estimator.tuned_hits = 2;
        m.estimator.prior_hits = 1;
        m.estimator.est_err.record_us(40.0);
        let r = m.render();
        assert!(r.contains("estimator: measured=5 tuned=2 prior=1"), "{r}");
    }

    #[test]
    fn render_shows_decide_histogram_when_present() {
        let mut m = ServeMetrics::default();
        m.span_us = 1e6;
        assert!(!m.render().contains("scheduler:"), "no line before decides");
        m.jit.decide_ns.record_us(1_500.0);
        m.jit.decide_ns.record_us(2_500.0);
        m.jit.buckets_reused = 7;
        m.jit.buckets_repacked = 3;
        let r = m.render();
        assert!(r.contains("scheduler: decides=2"), "{r}");
        assert!(r.contains("buckets_reused=7"), "{r}");
        assert!(r.contains("buckets_repacked=3"), "{r}");
    }

    #[test]
    fn render_shows_jit_stats_when_present() {
        let mut m = ServeMetrics::default();
        m.complete(0, SloClass::Standard, 1_000.0, true);
        m.span_us = 1e6;
        assert!(!m.render().contains("jit:"), "no jit line before launches");
        m.jit.launches = 4;
        m.jit.ops = 12;
        m.jit.evictions = 1;
        let r = m.render();
        assert!(r.contains("jit:"));
        assert!(r.contains("mean_pack=3.00"));
        assert!(r.contains("evictions=1"));
    }
}
