//! The serving loop — a thin driver over the OoO JIT core.
//!
//! There is exactly ONE scheduler in this repo: `compiler::{window,
//! scheduler, jit}`. The serving layer no longer re-implements EDF/hold
//! logic; it maps requests onto the JIT's declarative dispatch IR and lets
//! the shared core make every decision:
//!
//! * each **(tenant, model)** pair is a [`StreamId`] (a stream of
//!   execution in the paper's sense);
//! * each **model** is a coalescing *group*: requests for one model pack
//!   into one launch (up to the model's largest compiled batch variant),
//!   requests for different models never share a launch;
//! * each **request** is a [`DispatchRequest`] carrying its SLO and its
//!   input row as the attached payload — marked *independent* of its
//!   stream's earlier requests (stateless inference), so a hot tenant's
//!   burst rides one superkernel launch instead of serializing into
//!   singleton packs (see [`Server::independent_streams`]);
//! * a pack launch executes as one padded model batch through
//!   [`ModelBackend::execute`] (the [`ServeExecutor`] adapter).
//!
//! Four drive modes, one core:
//!
//! * [`Server::replay`] — virtual-paced arrivals, real measured service
//!   times, synchronous `pump`. Deterministic given a trace and a
//!   deterministic backend.
//! * [`Server::replay_placed`] — the multi-device virtual-time replay:
//!   launches route through a [`crate::placement`] table onto per-worker
//!   device timelines (heterogeneous speeds, per-class learned
//!   estimates), with optional hot-group rebalancing. Deterministic.
//! * [`Server::run_realtime`] — wall-clock arrivals from a generator
//!   thread, launches executed inline (`issue_ready` → `run_issued` →
//!   `finish_launch`).
//! * [`Server::run_realtime_pooled`] / [`Server::run_realtime_placed`] —
//!   the concurrent launch stage: launches fan out to a [`StatefulPool`]
//!   where each worker owns its own backend, routed to the least-loaded
//!   replica of the launch's group in the placement table; window
//!   capacity is the admission backstop.
//!
//! Admission and the scheduler share one estimator
//! ([`ServeExecutor::estimate_group_us`]), priced at the *padded* compiled
//! variant that will actually run — they can no longer disagree.
//!
//! **Threading model of the wall-clock drivers** (`run_realtime*`; see
//! [`crate::serve::frontend`] for the full contract): a generator thread
//! paces client arrivals into an intake channel; with
//! [`Server::frontend`] set (the default) a dedicated *frontend stage*
//! thread owns that channel and the admission gate, pricing every request
//! against the [`frontend::AdmissionView`] snapshot the scheduler thread
//! publishes once per iteration — so a tenant's accept/reject never waits
//! on an issue/launch/collect iteration. Accepted requests flow on to the
//! scheduler thread, which owns the JIT window, the clock, the launch
//! pool and the per-worker backlog accounting, and is the only snapshot
//! writer. The virtual-time `replay*` drivers keep the synchronous gate
//! for determinism, but price through the same `GroupView` path, so the
//! two gates cannot disagree on identical state.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compiler::ir::{DispatchRequest, StreamId, TensorOp};
use crate::compiler::jit::{
    JitCompiler, JitConfig, OpCompletion, PackExecutor, PackMember, PackRun,
};
use crate::compiler::coalescer::{Coalescer, SuperKernel};
use crate::compiler::scheduler::Policy;
use crate::gpu::device::DeviceSpec;
use crate::gpu::kernel::KernelDesc;
use crate::placement::{
    DeviceTopology, Placer, PlacementTable, RebalanceConfig, Rebalancer,
};
use crate::runtime::executor::{ModelExec, PjrtExecutor};
use crate::runtime::golden;
use crate::serve::admission::{Admission, Admit};
use crate::serve::frontend::{
    self, AdmissionView, FrontendGate, FrontendReport, GateExtras, GateRequest,
    ViewCell, STALE_VIEW_US,
};
use crate::serve::metrics::ServeMetrics;
use crate::util::stats::Ewma;
use crate::util::threadpool::{Stage, StatefulPool};
use crate::workload::trace::Trace;
use crate::Result;

/// Batching policy.
#[derive(Debug, Clone)]
pub enum BatchPolicy {
    /// Batch-1 FIFO (the early-binding baseline).
    NoBatching,
    /// SLO-aware coalescing (the paper's approach).
    Coalescing {
        /// Max hold time for the oldest queued request, µs.
        window_us: f64,
        /// Launch as soon as this many requests are queued.
        target_batch: u32,
        /// Slack reserve before a deadline forces a launch, µs.
        safety_margin_us: f64,
    },
}

impl BatchPolicy {
    /// Default coalescing parameters.
    pub fn coalescing() -> Self {
        BatchPolicy::Coalescing {
            window_us: 3_000.0,
            target_batch: 8,
            safety_margin_us: 1_000.0,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::NoBatching => "batch1-fifo",
            BatchPolicy::Coalescing { .. } => "ooo-coalescing",
        }
    }

    /// Lower the serving policy onto the JIT core's knobs: per-model pack
    /// caps (largest compiled variant) and the shared scheduler policy.
    fn jit_config(&self, models: &[ModelSlot], window_capacity: usize) -> JitConfig {
        let max_b = models
            .iter()
            .map(|m| m.max_batch as usize)
            .max()
            .unwrap_or(1)
            .max(1);
        let (policy, max_problems) = match *self {
            BatchPolicy::NoBatching => (
                Policy {
                    coalesce_window_us: 0.0,
                    target_pack: 1,
                    safety_margin_us: 0.0,
                    ..Policy::default()
                },
                1,
            ),
            BatchPolicy::Coalescing {
                window_us,
                target_batch,
                safety_margin_us,
            } => (
                Policy {
                    coalesce_window_us: window_us,
                    target_pack: (target_batch as usize).max(1),
                    safety_margin_us,
                    ..Policy::default()
                },
                max_b,
            ),
        };
        let mut coalescer = Coalescer::new(max_problems, 1.0);
        for (g, m) in models.iter().enumerate() {
            coalescer
                .group_caps
                .insert(g as u64, (m.max_batch as usize).max(1));
        }
        JitConfig {
            policy,
            coalescer,
            window_capacity,
            packing_overhead_us: 0.0,
        }
    }
}

/// Backend abstraction (real PJRT or a test stub).
pub trait ModelBackend {
    /// Execute a batch of rows on a model.
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec>;
    /// Estimated service time for a batch of `n`, µs. Implementations
    /// should price the padded variant that `n` rows would actually run.
    fn estimate_us(&self, model: &str, n: u32) -> f64;
    /// Largest compiled batch.
    fn max_batch(&self, model: &str) -> u32;
    /// Input feature count.
    fn d_in(&self, model: &str) -> usize;
    /// The batch size `n` rows actually execute at (smallest compiled
    /// variant that fits). Defaults to no padding knowledge.
    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        n.max(1).min(self.max_batch(model).max(1))
    }
}

impl<B: ModelBackend + ?Sized> ModelBackend for &mut B {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        (**self).execute(model, rows)
    }

    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        (**self).estimate_us(model, n)
    }

    fn max_batch(&self, model: &str) -> u32 {
        (**self).max_batch(model)
    }

    fn d_in(&self, model: &str) -> usize {
        (**self).d_in(model)
    }

    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        (**self).padded_batch(model, n)
    }
}

impl ModelBackend for PjrtExecutor {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        PjrtExecutor::execute_model(self, model, rows)
    }

    /// Service-time estimate for `n` rows: the *padded compiled variant*
    /// that will actually run, using the learned per-artifact latency when
    /// available, else the FLOPS-proportional prior scaled by the padded
    /// batch (not the raw `n` — underestimating the padded launch made the
    /// old batcher hold too long near deadlines).
    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        let Ok(entry) = self.manifest().model(model) else {
            return 1_000.0;
        };
        let per_query = entry.flops_per_query as f64;
        match entry.variant_for(n.max(1)) {
            Some(art) => self.estimate_file(&art.file, per_query * art.batch as f64),
            // batch exceeds the largest variant: extrapolate on the prior
            None => per_query * n.max(1) as f64 / (self.prior_gflops * 1e3),
        }
    }

    fn max_batch(&self, model: &str) -> u32 {
        self.manifest()
            .model(model)
            .map(|e| e.max_batch())
            .unwrap_or(1)
    }

    fn d_in(&self, model: &str) -> usize {
        self.manifest()
            .model(model)
            .map(|e| e.d_in as usize)
            .unwrap_or(0)
    }

    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        self.manifest()
            .model(model)
            .ok()
            .and_then(|e| e.variant_for(n.max(1)).map(|a| a.batch))
            .unwrap_or_else(|| self.max_batch(model))
    }
}

/// One served model: the coalescing-group table entry.
#[derive(Debug, Clone)]
pub struct ModelSlot {
    /// Manifest model name.
    pub name: String,
    /// Input feature count.
    pub d_in: usize,
    /// Largest compiled batch variant.
    pub max_batch: u32,
}

/// Adapter: executes JIT packs as padded model batches on a
/// [`ModelBackend`]. This is what makes `JitCompiler` the single serving
/// core — estimation (admission + scheduler) and execution both live here.
pub struct ServeExecutor<B: ModelBackend> {
    backend: B,
    models: Vec<ModelSlot>,
    /// learned per-(device class, group, padded batch) service time, µs —
    /// keyed per class so a t4 observation never updates a v100 estimate
    est: HashMap<(u32, u64, u32), Ewma>,
    /// relative speed per device class (index = class id); a single 1.0
    /// entry for the legacy single-device drive modes
    class_speeds: Vec<f64>,
    /// primary device class per group (the estimation target for
    /// admission and the scheduler); groups default to class 0
    group_class: HashMap<u64, u32>,
}

impl<B: ModelBackend> ServeExecutor<B> {
    /// New adapter over a backend and the run's model table.
    pub fn new(backend: B, models: Vec<ModelSlot>) -> Self {
        ServeExecutor {
            backend,
            models,
            est: HashMap::new(),
            class_speeds: vec![1.0],
            group_class: HashMap::new(),
        }
    }

    /// Borrow the backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// The model table (group id = index).
    pub fn models(&self) -> &[ModelSlot] {
        &self.models
    }

    /// Install the fleet's device-class speed table (relative throughput,
    /// index = class id). The placed drivers call this once at startup.
    pub fn set_class_speeds(&mut self, speeds: Vec<f64>) {
        if !speeds.is_empty() {
            self.class_speeds = speeds;
        }
    }

    /// Pin a group's primary device class (follows the placement table's
    /// primary replica; updated again after every rebalance).
    pub fn set_group_class(&mut self, group: u64, class: u32) {
        self.group_class.insert(group, class);
    }

    /// The device class a group's estimates are currently priced on.
    pub fn class_of_group(&self, group: u64) -> u32 {
        self.group_class.get(&group).copied().unwrap_or(0)
    }

    fn speed_of_class(&self, class: u32) -> f64 {
        self.class_speeds
            .get(class as usize)
            .copied()
            .unwrap_or(1.0)
            .max(1e-9)
    }

    /// Estimated service time of `n` queued requests for a model group,
    /// priced at the padded compiled variant that would actually run on
    /// the group's *primary device class* — the ONE estimator shared by
    /// admission and the scheduler.
    pub fn estimate_group_us(&self, group: u64, n: u32) -> f64 {
        self.estimate_group_on_class_us(group, self.class_of_group(group), n)
    }

    /// Estimate for an explicit device class: the class's learned EWMA
    /// when observed, else the backend prior scaled by the class's
    /// relative speed (a t4 runs the same padded variant ~2× longer than
    /// the v100 reference).
    pub fn estimate_group_on_class_us(&self, group: u64, class: u32, n: u32) -> f64 {
        let slot = &self.models[group as usize];
        let padded = self.backend.padded_batch(&slot.name, n);
        match self.est.get(&(class, group, padded)).and_then(|e| e.value()) {
            Some(v) => v,
            None => self.backend.estimate_us(&slot.name, n) / self.speed_of_class(class),
        }
    }

    /// Estimates for launches of 1..=cap ops of a group — the admission
    /// snapshot's table — memoized per padded compiled variant: pow2-ish
    /// padding collapses the table to ~log(cap) distinct estimator
    /// evaluations instead of cap. Entry k equals
    /// `estimate_group_us(group, k + 1)` exactly (`cap` never exceeds the
    /// group's largest compiled variant, so the padded batch determines
    /// the estimate).
    pub fn estimate_group_table_us(&self, group: u64, cap: u32) -> Vec<f64> {
        let slot = &self.models[group as usize];
        let class = self.class_of_group(group);
        let mut cache: HashMap<u32, f64> = HashMap::new();
        (1..=cap.max(1))
            .map(|n| {
                let padded = self.backend.padded_batch(&slot.name, n);
                *cache
                    .entry(padded)
                    .or_insert_with(|| self.estimate_group_on_class_us(group, class, n))
            })
            .collect()
    }

    fn observe_group(&mut self, class: u32, group: u64, padded: u32, us: f64) {
        self.est
            .entry((class, group, padded))
            .or_insert_with(|| Ewma::new(0.3))
            .observe(us);
    }
}

impl<B: ModelBackend> PackExecutor<Vec<f32>> for ServeExecutor<B> {
    fn estimate_pack_us(&self, _k: &KernelDesc, ops: &[&TensorOp]) -> f64 {
        match ops.first() {
            Some(op) => self.estimate_group_us(op.group, ops.len() as u32),
            None => 0.0,
        }
    }

    fn execute_pack(
        &mut self,
        sk: &SuperKernel,
        members: &[PackMember<'_, Vec<f32>>],
    ) -> PackRun {
        let group = members.first().map(|m| m.op.group).unwrap_or(0);
        let name = self.models[group as usize].name.clone();
        let rows: Vec<Vec<f32>> = members.iter().map(|m| m.payload.clone()).collect();
        match self.backend.execute(&name, &rows) {
            Ok(exec) => PackRun {
                duration_us: exec.duration_us,
                executed: exec.batch,
                ok: true,
                device_class: 0,
            },
            Err(e) => {
                crate::util::logging::emit(
                    crate::util::logging::Level::Error,
                    format_args!("execute {name} failed: {e}"),
                );
                PackRun {
                    duration_us: 0.0,
                    executed: sk.kernel.problems,
                    ok: false,
                    device_class: 0,
                }
            }
        }
    }

    fn observe_pack(&mut self, _sk: &SuperKernel, ops: &[&TensorOp], run: &PackRun) {
        if !run.ok {
            return;
        }
        if let Some(op) = ops.first() {
            self.observe_group(run.device_class, op.group, run.executed, run.duration_us);
        }
    }
}

/// Deterministic simulator backend: fixed per-launch overhead plus a
/// per-row cost, padding up to power-of-two compiled variants like the
/// real artifact set. Drives `vliwd bench` and the CI smoke run (no PJRT
/// artifacts required) and the serving unit tests.
#[derive(Debug, Clone)]
pub struct SimBackend {
    /// Fixed per-launch overhead, µs.
    pub fixed_us: f64,
    /// Marginal cost per padded row, µs.
    pub per_row_us: f64,
    /// Largest compiled batch variant.
    pub max_b: u32,
    /// Input feature count (every model).
    pub d_in: usize,
}

impl Default for SimBackend {
    fn default() -> Self {
        SimBackend {
            fixed_us: 500.0,
            per_row_us: 50.0,
            max_b: 16,
            d_in: 4,
        }
    }
}

impl ModelBackend for SimBackend {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        let batch = self.padded_batch(model, rows.len() as u32);
        let dur = self.fixed_us + self.per_row_us * batch as f64;
        Ok(ModelExec {
            outputs: rows.iter().map(|_| vec![0.0; 4]).collect(),
            batch,
            duration_us: dur,
        })
    }

    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        let padded = self.padded_batch(model, n);
        self.fixed_us + self.per_row_us * padded as f64
    }

    fn max_batch(&self, _m: &str) -> u32 {
        self.max_b
    }

    fn d_in(&self, _m: &str) -> usize {
        self.d_in
    }

    fn padded_batch(&self, _m: &str, n: u32) -> u32 {
        n.max(1).next_power_of_two().min(self.max_b)
    }
}

/// Serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// All metrics.
    pub metrics: ServeMetrics,
    /// Policy used.
    pub policy: &'static str,
}

impl ServeReport {
    /// Render for humans.
    pub fn render(&self) -> String {
        format!("policy={}\n{}", self.policy, self.metrics.render())
    }
}

/// A (tenant, model-group) pair is one stream of execution: per-tenant
/// program order within a model, full independence across pairs. Stream
/// ids are interned per run in first-appearance order (no bit packing —
/// arbitrary tenant ids can never collide).
fn intern_stream(
    streams: &mut BTreeMap<(u32, u64), u32>,
    tenant: u32,
    group: u64,
) -> StreamId {
    let next = streams.len() as u32;
    StreamId(*streams.entry((tenant, group)).or_insert(next))
}

/// Build the run's model table (group id = sorted-name index) from the
/// trace and the backend's manifest knowledge.
fn model_slots<B: ModelBackend>(
    backend: &B,
    trace: &Trace,
) -> (Vec<ModelSlot>, BTreeMap<String, u64>) {
    let mut names: BTreeSet<String> =
        trace.tenants.iter().map(|t| t.model.clone()).collect();
    for r in &trace.requests {
        names.insert(r.model.clone());
    }
    let slots: Vec<ModelSlot> = names
        .iter()
        .map(|n| ModelSlot {
            name: n.clone(),
            d_in: backend.d_in(n),
            max_batch: backend.max_batch(n).max(1),
        })
        .collect();
    let index: BTreeMap<String, u64> = slots
        .iter()
        .enumerate()
        .map(|(i, s)| (s.name.clone(), i as u64))
        .collect();
    (slots, index)
}

/// Seed the placement table: LPT over each group's total estimated work
/// in the trace (batch-1 estimates x request count). Shared by the placed
/// replay and realtime drivers so their initial placements cannot diverge.
fn seed_placement<B: ModelBackend>(
    backend: &B,
    trace: &Trace,
    index: &BTreeMap<String, u64>,
    groups: u64,
    topo: &DeviceTopology,
) -> PlacementTable {
    let mut work: BTreeMap<u64, f64> = (0..groups).map(|g| (g, 0.0)).collect();
    for r in &trace.requests {
        *work.entry(index[&r.model]).or_insert(0.0) += backend.estimate_us(&r.model, 1);
    }
    let costs: Vec<(u64, f64)> = work.into_iter().collect();
    Placer::place(&costs, topo)
}

/// Effective drain parallelism of a group's replica set: how many
/// primary-class-equivalents serve it (Σ replica speed ÷ primary-replica
/// speed, so the units match the estimate, which is priced on the primary
/// class). Two equal replicas = 2.0; a v100 primary with a k80 replica =
/// ~1.25 — dividing the drain by the raw replica count would underprice
/// it on mixed fleets and re-admit doomed requests.
fn drain_parallelism(table: &PlacementTable, topo: &DeviceTopology, group: u64) -> f64 {
    let reps = table.replicas_of(group);
    match reps.first() {
        None => 1.0,
        Some(p) => {
            let primary = topo.speed_of_worker(*p).max(1e-9);
            (reps.iter().map(|w| topo.speed_of_worker(*w)).sum::<f64>() / primary)
                .max(1.0)
        }
    }
}

/// The wall-clock drivers' launch-stage configuration: the device
/// topology, the group→replicas placement table, and the optional
/// rebalancer. `None` on the inline (no pool) and legacy hash-routed
/// paths.
type PlacedState = Option<(DeviceTopology, PlacementTable, Option<Rebalancer>)>;

/// Admission gate inputs for one group under the current launch-stage
/// configuration: (drain parallelism, measured worker backlog).
///
/// * placed (placement table present): speed-weighted replica
///   parallelism plus the least-loaded replica's booked backlog;
/// * pooled but unplaced (legacy hash routing): the hash-routed worker's
///   booked backlog — the worker every launch of the group lands on.
///   This signal was maintained by the launch stage but never consulted,
///   so the gate priced pooled-unplaced drains queue-blind;
/// * inline (no pool): nothing measured; the JIT's in-flight term prices
///   the drain.
fn gate_inputs(
    placed: &PlacedState,
    pool_workers: usize,
    worker_backlog: &[f64],
    group: u64,
) -> (f64, Option<f64>) {
    match placed {
        Some((topo, table, _)) => {
            let b = table
                .replicas_of(group)
                .iter()
                .map(|w| worker_backlog.get(*w).copied().unwrap_or(0.0))
                .fold(f64::INFINITY, f64::min);
            (
                drain_parallelism(table, topo, group),
                Some(if b.is_finite() { b } else { 0.0 }),
            )
        }
        None if pool_workers > 0 => (
            1.0,
            Some(
                worker_backlog
                    .get(group as usize % pool_workers)
                    .copied()
                    .unwrap_or(0.0),
            ),
        ),
        None => (1.0, None),
    }
}

/// Build the full admission snapshot the frontend stage prices against
/// (one [`frontend::GroupView`] per group via the shared
/// [`frontend::snapshot_group`], plus the drain counters that net off the
/// frontend's accept counts).
fn build_view<B: ModelBackend>(
    seq: u64,
    jit: &JitCompiler<ServeExecutor<&mut B>, Vec<f32>>,
    placed: &PlacedState,
    pool_workers: usize,
    worker_backlog: &[f64],
    drained: (&[u64], &[u64]),
) -> AdmissionView {
    let groups = drained.0.len() as u64;
    AdmissionView {
        seq,
        now_us: jit.now_us,
        published: Instant::now(),
        groups: (0..groups)
            .map(|g| {
                let (par, backlog) = gate_inputs(placed, pool_workers, worker_backlog, g);
                frontend::snapshot_group(jit, g, par, backlog, true)
            })
            .collect(),
        drained: drained.0.to_vec(),
        drained_by_stream: drained.1.to_vec(),
    }
}

/// Pin every group's primary estimation class to its current primary
/// replica's device class (called at startup and after each rebalance).
fn repin_group_classes<B: ModelBackend>(
    exec: &mut ServeExecutor<B>,
    table: &PlacementTable,
    topo: &DeviceTopology,
    groups: u64,
) {
    for g in 0..groups {
        if let Some(w) = table.primary_of(g) {
            exec.set_group_class(g, topo.class_of(w));
        }
    }
}

fn record_completion(metrics: &mut ServeMetrics, c: &OpCompletion) {
    let tenant = c.op.tag as u32;
    if c.failed {
        metrics.drop_request(tenant);
    } else {
        metrics.complete(tenant, c.latency_us(), c.met_deadline);
    }
}

/// One request at the admission gate (bundled so the drivers cannot
/// transpose the adjacent time/flag fields at a call site).
struct AdmitReq {
    group: u64,
    tenant: u32,
    arrival_us: f64,
    deadline_us: f64,
    independent: bool,
    /// Effective drain parallelism of the group's serving workers (speed-
    /// weighted replica count from [`drain_parallelism`]; 1.0 for the
    /// single-device drive modes) — the drain estimate's divisor.
    parallelism: f64,
    /// Measured backlog on the group's least-loaded replica timeline, µs
    /// (the placed virtual-time driver's device queues, which already
    /// include every issued launch — other groups' included). `Some`
    /// replaces the JIT's in-flight estimate term, which cannot see
    /// device queueing and would underprice launches waiting for a busy
    /// device. `None` for drive modes without device timelines.
    device_backlog_us: Option<f64>,
    row: Vec<f32>,
}

/// One client request in flight from the generator (client side) to the
/// admission gate — sync or frontend.
struct Incoming {
    tenant: u32,
    group: u64,
    slo_us: f64,
    arrival: Instant,
    row: Vec<f32>,
}

/// An accepted, pre-priced request in flight from the frontend stage to
/// the scheduler thread. The gate decision is already made; the scheduler
/// only timestamps it into the window (backpressure backstop aside).
struct Admitted {
    stream: StreamId,
    group: u64,
    tenant: u32,
    slo_us: f64,
    arrival: Instant,
    row: Vec<f32>,
}

/// The post-accept tail shared by both gates (bundled so the two call
/// sites cannot drift): what the scheduler needs to timestamp an accepted
/// request into the window.
struct Accepted {
    stream: StreamId,
    group: u64,
    tenant: u32,
    slo_us: f64,
    arrival_us: f64,
    independent: bool,
    row: Vec<f32>,
}

/// Build the dispatch request for an accepted serving request and submit
/// it at its true arrival; the window backstop sheds on overflow
/// (recorded as a drop). The ONE request-construction path behind the
/// synchronous gate and the frontend drain.
fn submit_accepted<B: ModelBackend>(
    jit: &mut JitCompiler<ServeExecutor<&mut B>, Vec<f32>>,
    metrics: &mut ServeMetrics,
    slots: &[ModelSlot],
    a: Accepted,
) {
    let slot = &slots[a.group as usize];
    let req = DispatchRequest::new(
        a.stream,
        KernelDesc::gemm(1, slot.d_in as u32, 1),
        a.slo_us,
    )
    .with_group(a.group)
    .with_tag(a.tenant as u64)
    .with_independent(a.independent);
    if jit.submit_at(req, a.arrival_us, a.row).is_none() {
        // window full: the backpressure backstop sheds the request
        metrics.drop_request(a.tenant);
    }
}

/// The admission frontend stage's thread body: drain the intake channel,
/// price each request against the latest published [`AdmissionView`],
/// forward accepts to the scheduler, turn rejects around locally. Exits
/// when the intake side disconnects; its thread-local accounting
/// ([`FrontendReport`]) comes home through the stage's join.
fn frontend_loop(
    intake_rx: mpsc::Receiver<Incoming>,
    acc_tx: mpsc::Sender<Admitted>,
    cell: Arc<ViewCell>,
    admission: Admission,
    groups: usize,
    independent: bool,
    t0: Instant,
) -> FrontendReport {
    let mut gate = FrontendGate::new(admission, groups);
    let mut report = FrontendReport::default();
    loop {
        let first = match intake_rx.recv_timeout(Duration::from_micros(500)) {
            Ok(inc) => inc,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let mut batch = vec![first];
        while let Ok(inc) = intake_rx.try_recv() {
            batch.push(inc);
        }
        for inc in batch {
            let view = cell.load();
            let now_us = t0.elapsed().as_secs_f64() * 1e6;
            let arrival_us =
                inc.arrival.saturating_duration_since(t0).as_secs_f64() * 1e6;
            let stream = gate.intern(inc.tenant, inc.group);
            let greq = GateRequest {
                stream,
                independent,
                deadline_us: arrival_us + inc.slo_us,
            };
            let decision = gate.decide(&view, inc.group, &greq, now_us);
            report.decisions += 1;
            report
                .admission_latency
                .record_us(inc.arrival.elapsed().as_secs_f64() * 1e6);
            if view.published.elapsed().as_secs_f64() * 1e6 > STALE_VIEW_US {
                report.stale_decisions += 1;
            }
            // a send can only fail at shutdown (scheduler gone): the
            // request is shed, counted like any other reject
            let accepted = decision == Admit::Accept
                && acc_tx
                    .send(Admitted {
                        stream,
                        group: inc.group,
                        tenant: inc.tenant,
                        slo_us: inc.slo_us,
                        arrival: inc.arrival,
                        row: inc.row,
                    })
                    .is_ok();
            if !accepted {
                *report.drops.entry(inc.tenant).or_insert(0) += 1;
            }
        }
    }
    report
}

/// The multi-tenant server.
pub struct Server<B: ModelBackend> {
    backend: B,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Admission control.
    pub admission: Admission,
    /// JIT issue-window capacity — the backpressure backstop behind
    /// admission.
    pub window_capacity: usize,
    /// Treat requests within one (tenant, model) stream as independent
    /// (stateless inference, the default): a tenant's burst may then
    /// coalesce into one launch and issue out of arrival order within its
    /// stream. Turn off for deployments whose per-stream requests carry
    /// state — program order then binds and at most one request per stream
    /// rides each launch.
    pub independent_streams: bool,
    /// Run admission on a dedicated frontend stage thread (the default)
    /// in the wall-clock drivers, so tenant accept/reject decisions never
    /// wait on a scheduler iteration — see [`crate::serve::frontend`].
    /// With the flag off the gate runs synchronously on the scheduler
    /// thread between channel drains (the pre-frontend behavior, kept for
    /// comparison benches). The virtual-time `replay*` drivers always use
    /// the synchronous gate: a wall-clock frontend would race the virtual
    /// clock and break replay determinism.
    pub frontend: bool,
}

impl<B: ModelBackend> Server<B> {
    /// New server.
    pub fn new(backend: B, policy: BatchPolicy) -> Self {
        Server {
            backend,
            policy,
            admission: Admission::default(),
            window_capacity: 1024,
            independent_streams: true,
            frontend: true,
        }
    }

    /// Borrow the backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (warmup etc.).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Admission decision for one request; on Accept, submits it into the
    /// JIT (window backpressure sheds as a backstop). Records drops.
    ///
    /// Pricing goes through the same [`frontend::GroupView`] the async
    /// frontend stage consumes, built synchronously from live JIT state —
    /// see [`frontend::GroupView::drain_est_us`] for the drain model
    /// (per-launch queue and in-flight pricing, speed-weighted replica
    /// parallelism, the measured device backlog replacing the in-flight
    /// term when known) and [`Admission::decide`] for the separate
    /// queued/in-flight contracts. One pricing implementation behind both
    /// gates means they cannot disagree on identical state.
    fn admit_request(
        jit: &mut JitCompiler<ServeExecutor<&mut B>, Vec<f32>>,
        streams: &mut BTreeMap<(u32, u64), u32>,
        admission: &Admission,
        metrics: &mut ServeMetrics,
        slots: &[ModelSlot],
        r: AdmitReq,
    ) {
        let AdmitReq {
            group,
            tenant,
            arrival_us,
            deadline_us,
            independent,
            parallelism,
            device_backlog_us,
            row,
        } = r;
        let stream = intern_stream(streams, tenant, group);
        // independent-mode pricing never reads the per-stream depth list,
        // so the synchronous gate skips that window scan
        let gview = frontend::snapshot_group(
            jit,
            group,
            parallelism,
            device_backlog_us,
            !independent,
        );
        let greq = GateRequest {
            stream,
            independent,
            deadline_us,
        };
        if gview.decide(admission, &greq, GateExtras::default(), jit.now_us)
            == Admit::Reject
        {
            metrics.drop_request(tenant);
            return;
        }
        submit_accepted(
            jit,
            metrics,
            slots,
            Accepted {
                stream,
                group,
                tenant,
                slo_us: deadline_us - arrival_us,
                arrival_us,
                independent,
                row,
            },
        );
    }

    /// Replay a trace in virtual time with real service executions,
    /// entirely through the JIT core. Request payloads are deterministic
    /// hash01 rows.
    pub fn replay(&mut self, trace: &Trace) -> ServeReport {
        let mut metrics = ServeMetrics::default();
        let (slots, index) = model_slots(&self.backend, trace);
        let cfg = self.policy.jit_config(&slots, self.window_capacity);
        let policy_name = self.policy.name();
        let admission = self.admission.clone();
        let independent = self.independent_streams;
        let mut jit: JitCompiler<ServeExecutor<&mut B>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut self.backend, slots.clone()),
            );
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        let reqs = &trace.requests;
        let mut next = 0usize;
        loop {
            // 1. admit everything that has arrived (true arrival times)
            while next < reqs.len() && reqs[next].arrival_us <= jit.now_us + 1e-9 {
                let r = &reqs[next];
                next += 1;
                let group = index[&r.model];
                let row =
                    golden::gen_hash01(slots[group as usize].d_in, r.id.wrapping_mul(7919));
                Self::admit_request(
                    &mut jit,
                    &mut streams,
                    &admission,
                    &mut metrics,
                    &slots,
                    AdmitReq {
                        group,
                        tenant: r.tenant,
                        arrival_us: r.arrival_us,
                        deadline_us: r.deadline_us,
                        independent,
                        parallelism: 1.0,
                        device_backlog_us: None,
                        row,
                    },
                );
            }
            // 2. let the core launch everything the policy allows
            let (done, wake) = jit.pump();
            for c in &done {
                record_completion(&mut metrics, c);
            }
            for l in jit.take_launches() {
                if l.ok {
                    metrics.launch(&l);
                }
            }
            // 3. advance the virtual clock to the next event
            let next_arrival = reqs.get(next).map(|r| r.arrival_us);
            match (wake, next_arrival) {
                (None, None) => {
                    debug_assert!(jit.window.is_empty(), "deadlocked window");
                    break;
                }
                (None, Some(t)) => jit.advance_to(t),
                (Some(w), None) => jit.advance_to(w),
                (Some(w), Some(t)) => jit.advance_to(w.min(t)),
            }
        }
        metrics.span_us = jit.now_us;
        metrics.jit = jit.stats.clone();
        ServeReport {
            metrics,
            policy: policy_name,
        }
    }

    /// Multi-device virtual-time replay: the placement-aware sibling of
    /// [`Server::replay`]. Launches issue through the one JIT core, then
    /// route to topology workers via a placement table (least-busy
    /// replica); each worker keeps its own busy-until timeline, so a
    /// replicated group drains on several devices in parallel. Execution
    /// durations come from the shared backend scaled by each device's
    /// relative speed; learned estimates are keyed per device class.
    /// With `rebalance` set, hot groups replicate onto cooler devices and
    /// cold groups migrate off hot ones between observation windows.
    ///
    /// Deterministic given a trace, a deterministic backend, and a fixed
    /// topology. Returns the report plus the final placement table.
    pub fn replay_placed(
        &mut self,
        trace: &Trace,
        topo: &DeviceTopology,
        rebalance: Option<RebalanceConfig>,
    ) -> (ServeReport, PlacementTable) {
        let mut metrics = ServeMetrics::default();
        let (slots, index) = model_slots(&self.backend, trace);
        let groups = slots.len() as u64;
        let mut table = seed_placement(&self.backend, trace, &index, groups, topo);
        let mut rebal = rebalance.map(|c| Rebalancer::new(c, topo.len()));

        let cfg = self.policy.jit_config(&slots, self.window_capacity);
        let policy_name = self.policy.name();
        let admission = self.admission.clone();
        let independent = self.independent_streams;
        let mut exec = ServeExecutor::new(&mut self.backend, slots.clone());
        exec.set_class_speeds(topo.class_speeds());
        repin_group_classes(&mut exec, &table, topo, groups);
        let mut jit: JitCompiler<ServeExecutor<&mut B>, Vec<f32>> =
            JitCompiler::with_payloads(cfg, exec);
        for w in topo.workers() {
            metrics.ensure_device(w.worker, w.spec.name);
        }

        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        // per-worker busy-until time: the device timelines
        let mut free_at: Vec<f64> = vec![0.0; topo.len()];
        // issued-but-unfinished launches: (done_us, ticket, worker, group, run)
        let mut inflight: Vec<(f64, u64, usize, u64, PackRun)> = Vec::new();
        let reqs = &trace.requests;
        let mut next = 0usize;
        loop {
            // 1. admit everything that has arrived (true arrival times)
            while next < reqs.len() && reqs[next].arrival_us <= jit.now_us + 1e-9 {
                let r = &reqs[next];
                next += 1;
                let group = index[&r.model];
                let parallelism = drain_parallelism(&table, topo, group);
                // the true wait: queued work on the least-loaded replica
                let backlog = table
                    .replicas_of(group)
                    .iter()
                    .map(|w| (free_at[*w] - jit.now_us).max(0.0))
                    .fold(f64::INFINITY, f64::min);
                let backlog = if backlog.is_finite() { backlog } else { 0.0 };
                let row =
                    golden::gen_hash01(slots[group as usize].d_in, r.id.wrapping_mul(7919));
                Self::admit_request(
                    &mut jit,
                    &mut streams,
                    &admission,
                    &mut metrics,
                    &slots,
                    AdmitReq {
                        group,
                        tenant: r.tenant,
                        arrival_us: r.arrival_us,
                        deadline_us: r.deadline_us,
                        independent,
                        parallelism,
                        device_backlog_us: Some(backlog),
                        row,
                    },
                );
            }
            // 2. issue every launch the policy allows; route each to the
            // least-busy replica and queue it on that device's timeline
            let (launches, wake) = jit.issue_ready();
            for l in launches {
                let group = jit
                    .window
                    .get(l.pack.ops[0])
                    .map(|op| op.group)
                    .unwrap_or(0);
                let worker = table.route(group, &free_at);
                // re-price on the routed class: a slow replica running at
                // its own speed is not a straggler
                let est_routed = jit.executor().estimate_group_on_class_us(
                    group,
                    topo.class_of(worker),
                    l.pack.ops.len() as u32,
                );
                jit.reprice_pending(l.ticket, est_routed);
                let mut run = jit.run_issued(l.ticket);
                run.duration_us /= topo.speed_of_worker(worker).max(1e-9);
                run.device_class = topo.class_of(worker);
                let start = free_at[worker].max(jit.now_us);
                let done = start + run.duration_us;
                free_at[worker] = done;
                inflight.push((done, l.ticket, worker, group, run));
            }
            // 3. advance the virtual clock to the next event
            let next_done = inflight.iter().map(|e| e.0).fold(f64::INFINITY, f64::min);
            let next_arrival = reqs
                .get(next)
                .map(|r| r.arrival_us)
                .unwrap_or(f64::INFINITY);
            let t = next_done.min(next_arrival).min(wake.unwrap_or(f64::INFINITY));
            if !t.is_finite() {
                debug_assert!(jit.window.is_empty(), "deadlocked placed window");
                break;
            }
            jit.advance_to(t);
            // 4. fold in completions now due, in deterministic time order
            let mut due: Vec<(f64, u64, usize, u64, PackRun)> = Vec::new();
            let mut i = 0;
            while i < inflight.len() {
                if inflight[i].0 <= jit.now_us + 1e-9 {
                    due.push(inflight.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            due.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).expect("NaN done time").then(a.1.cmp(&b.1))
            });
            for (done_us, ticket, worker, group, run) in due {
                let (ok, dur) = (run.ok, run.duration_us);
                let completions = jit.finish_launch(ticket, done_us, run);
                for c in &completions {
                    record_completion(&mut metrics, c);
                }
                if ok {
                    metrics.device_launch(worker, topo.spec_of(worker).name, dur);
                    if let Some(rb) = rebal.as_mut() {
                        rb.observe_launch(group, worker, dur);
                    }
                }
            }
            for l in jit.take_launches() {
                if l.ok {
                    metrics.launch(&l);
                }
            }
            // 5. rebalance between observation windows; re-pin each
            // group's primary estimation class to its new primary replica
            if let Some(rb) = rebal.as_mut() {
                let actions = rb.maybe_rebalance(jit.now_us, &mut table, topo);
                if !actions.is_empty() {
                    repin_group_classes(jit.executor_mut(), &table, topo, groups);
                }
                metrics.replications = rb.stats.replications;
                metrics.migrations = rb.stats.migrations;
            }
        }
        metrics.span_us = jit.now_us;
        metrics.jit = jit.stats.clone();
        (
            ServeReport {
                metrics,
                policy: policy_name,
            },
            table,
        )
    }

    /// Threaded real-time mode: a generator thread paces the trace on the
    /// wall clock (compressed by `speedup`); the current thread drives the
    /// JIT core and executes launches inline. Returns wall-clock metrics.
    pub fn run_realtime(&mut self, trace: &Trace, speedup: f64) -> ServeReport
    where
        B: 'static,
    {
        self.realtime_loop(trace, speedup, None, None, None, false)
    }

    /// Concurrent real-time mode: launches fan out to `workers` pool
    /// workers, each owning its own backend built by `factory` on its own
    /// thread (the backend type need not be `Send`). The launch stage
    /// routes through a placement table over a homogeneous fleet (one
    /// device class), so superkernels for different models execute in
    /// parallel while one model's launches stay serialized (and
    /// cache-warm) on their placed worker.
    pub fn run_realtime_pooled<F>(
        &mut self,
        trace: &Trace,
        speedup: f64,
        workers: usize,
        factory: F,
    ) -> ServeReport
    where
        B: 'static,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let pool = StatefulPool::new(workers, factory);
        // placement routing over an anonymous homogeneous fleet; device
        // names are NOT reported — this mode runs on whatever hardware
        // the caller's backends really use, and metrics.devices staying
        // empty is the documented single-device-modes contract
        let topo = DeviceTopology::homogeneous(workers, DeviceSpec::v100());
        self.realtime_loop(trace, speedup, Some(&pool), Some(topo), None, false)
    }

    /// Device-placed real-time mode: one pool worker per topology device,
    /// each owning the backend `factory(worker, spec)` builds on its own
    /// thread. Launches route to the least-loaded replica of their
    /// group's placement-table entry; when `rebalance` is set, hot groups
    /// replicate onto cooler devices (and cold ones migrate off hot
    /// devices) as per-device load skews.
    pub fn run_realtime_placed<F>(
        &mut self,
        trace: &Trace,
        speedup: f64,
        topo: DeviceTopology,
        rebalance: Option<RebalanceConfig>,
        factory: F,
    ) -> ServeReport
    where
        B: 'static,
        F: Fn(usize, &DeviceSpec) -> B + Send + Sync + 'static,
    {
        let specs = topo.clone();
        let pool = StatefulPool::new(topo.len(), move |i| factory(i, specs.spec_of(i)));
        self.realtime_loop(trace, speedup, Some(&pool), Some(topo), rebalance, true)
    }

    fn realtime_loop(
        &mut self,
        trace: &Trace,
        speedup: f64,
        pool: Option<&StatefulPool<B>>,
        topo: Option<DeviceTopology>,
        rebalance: Option<RebalanceConfig>,
        report_devices: bool,
    ) -> ServeReport
    where
        B: 'static,
    {
        let (slots, index) = model_slots(&self.backend, trace);
        // placement for the pooled launch stage: LPT over each group's
        // total estimated work; each launch then routes to the
        // least-loaded replica of its group's table entry
        let groups = slots.len() as u64;
        let mut placed: PlacedState =
            match topo {
                Some(topo) if pool.is_some() => {
                    let table =
                        seed_placement(&self.backend, trace, &index, groups, &topo);
                    let rebal = rebalance.map(|c| Rebalancer::new(c, topo.len()));
                    Some((topo, table, rebal))
                }
                _ => None,
            };
        let gen_reqs: Vec<(f64, u32, u64, f64, u64)> = trace
            .requests
            .iter()
            .map(|r| {
                (
                    r.arrival_us / speedup,
                    r.tenant,
                    index[&r.model],
                    r.deadline_us - r.arrival_us,
                    r.id,
                )
            })
            .collect();
        let d_ins: Vec<usize> = slots.iter().map(|s| s.d_in).collect();
        let t0 = Instant::now();
        let (tx, rx) = mpsc::channel::<Incoming>();
        let gen = std::thread::spawn(move || {
            let g0 = Instant::now();
            for (at_us, tenant, group, slo, id) in gen_reqs {
                let target = Duration::from_micros(at_us as u64);
                let elapsed = g0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                let d_in = d_ins[group as usize];
                let _ = tx.send(Incoming {
                    tenant,
                    group,
                    slo_us: slo,
                    arrival: Instant::now(),
                    row: golden::gen_hash01(d_in, id.wrapping_mul(7919)),
                });
            }
        });

        let cfg = self.policy.jit_config(&slots, self.window_capacity);
        let policy_name = self.policy.name();
        let admission = self.admission.clone();
        let independent = self.independent_streams;
        let use_frontend = self.frontend;
        let mut metrics = ServeMetrics::default();
        let (res_tx, res_rx) =
            mpsc::channel::<(u64, std::result::Result<ModelExec, String>)>();
        let mut jit: JitCompiler<ServeExecutor<&mut B>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut self.backend, slots.clone()),
            );
        if let Some((topo, table, _)) = &placed {
            jit.executor_mut().set_class_speeds(topo.class_speeds());
            repin_group_classes(jit.executor_mut(), table, topo, groups);
            if report_devices {
                for w in topo.workers() {
                    metrics.ensure_device(w.worker, w.spec.name);
                }
            }
        }
        let wall_us = |t0: Instant| t0.elapsed().as_secs_f64() * 1e6;
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        // pooled-launch routing decisions, keyed by launch ticket:
        // (worker, group, routed-class estimate)
        let mut ticket_route: HashMap<u64, (usize, u64, f64)> = HashMap::new();
        // estimated un-finished work per pool worker, µs — admission's
        // device-backlog signal (conservative: head-job progress is not
        // subtracted; a wall-clock driver cannot observe it)
        let pool_workers = pool.map(|p| p.workers()).unwrap_or(0);
        let mut worker_backlog: Vec<f64> = vec![0.0; pool_workers];
        // cumulative per-group / per-stream requests drained from the
        // frontend's accepted channel into the window — published in every
        // snapshot so the frontend nets them off its own accept counters
        let mut drained: Vec<u64> = vec![0; groups as usize];
        let mut drained_by_stream: Vec<u64> = Vec::new();
        let mut view_seq: u64 = 0;
        // the admission frontend stage: it takes the intake receiver and
        // hands back accepted requests; `None` = synchronous gate
        let mut sync_rx: Option<mpsc::Receiver<Incoming>> = Some(rx);
        let fe =
            if use_frontend {
                let intake_rx = sync_rx.take().expect("intake receiver");
                let (acc_tx, acc_rx) = mpsc::channel::<Admitted>();
                let cell = ViewCell::new(build_view(
                    0,
                    &jit,
                    &placed,
                    pool_workers,
                    &worker_backlog,
                    (&drained, &drained_by_stream),
                ));
                let fe_cell = Arc::clone(&cell);
                let fe_admission = admission.clone();
                let n_groups = groups as usize;
                let stage = Stage::spawn("vliw-frontend", move || {
                    frontend_loop(
                        intake_rx,
                        acc_tx,
                        fe_cell,
                        fe_admission,
                        n_groups,
                        independent,
                        t0,
                    )
                });
                Some((acc_rx, cell, stage))
            } else {
                None
            };
        let mut disconnected = false;
        // snapshot publication control: republish when scheduler state
        // changed this iteration, or on a heartbeat at half the staleness
        // threshold (so idle ticks skip the rebuild without inflating the
        // frontend's stale-decision counter)
        let mut view_dirty = false;
        let mut last_publish = Instant::now();
        loop {
            // 1. drain this iteration's input — client arrivals on the
            // synchronous path, frontend-accepted requests otherwise
            // (bounded wait when idle); once the upstream side is gone
            // the channel stays empty — pace the loop with a short sleep
            // instead of spinning on it
            if disconnected {
                std::thread::sleep(Duration::from_micros(200));
            }
            if let Some(rx) = &sync_rx {
                let mut arrivals: Vec<Incoming> = Vec::new();
                if !disconnected {
                    match rx.recv_timeout(Duration::from_micros(500)) {
                        Ok(inc) => {
                            arrivals.push(inc);
                            while let Ok(inc) = rx.try_recv() {
                                arrivals.push(inc);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            disconnected = true
                        }
                    }
                }
                jit.advance_to(wall_us(t0));
                for inc in arrivals {
                    // the synchronous gate decides at drain time: the
                    // arrival→decision latency IS the channel wait
                    metrics.sync_admission_decision(
                        inc.arrival.elapsed().as_secs_f64() * 1e6,
                    );
                    let arrival_us =
                        inc.arrival.saturating_duration_since(t0).as_secs_f64() * 1e6;
                    let (parallelism, backlog) =
                        gate_inputs(&placed, pool_workers, &worker_backlog, inc.group);
                    Self::admit_request(
                        &mut jit,
                        &mut streams,
                        &admission,
                        &mut metrics,
                        &slots,
                        AdmitReq {
                            group: inc.group,
                            tenant: inc.tenant,
                            arrival_us,
                            deadline_us: arrival_us + inc.slo_us,
                            independent,
                            parallelism,
                            device_backlog_us: backlog,
                            row: inc.row,
                        },
                    );
                }
            } else if let Some((acc_rx, _, _)) = &fe {
                let mut accepted: Vec<Admitted> = Vec::new();
                if !disconnected {
                    match acc_rx.recv_timeout(Duration::from_micros(500)) {
                        Ok(a) => {
                            accepted.push(a);
                            while let Ok(a) = acc_rx.try_recv() {
                                accepted.push(a);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            disconnected = true
                        }
                    }
                }
                jit.advance_to(wall_us(t0));
                view_dirty |= !accepted.is_empty();
                for adm in accepted {
                    // how long the accepted request sat between threads
                    // before being priced into the window
                    metrics
                        .frontend_wait
                        .record_us(adm.arrival.elapsed().as_secs_f64() * 1e6);
                    // drain accounting advances whether or not the window
                    // backstop sheds — the frontend nets these counters
                    // off its cumulative accepts either way
                    drained[adm.group as usize] += 1;
                    let s = adm.stream.0 as usize;
                    if drained_by_stream.len() <= s {
                        drained_by_stream.resize(s + 1, 0);
                    }
                    drained_by_stream[s] += 1;
                    let arrival_us =
                        adm.arrival.saturating_duration_since(t0).as_secs_f64() * 1e6;
                    submit_accepted(
                        &mut jit,
                        &mut metrics,
                        &slots,
                        Accepted {
                            stream: adm.stream,
                            group: adm.group,
                            tenant: adm.tenant,
                            slo_us: adm.slo_us,
                            arrival_us,
                            independent,
                            row: adm.row,
                        },
                    );
                }
            }
            // 2. issue every launch the policy allows right now
            let (launches, _wake) = jit.issue_ready();
            view_dirty |= !launches.is_empty();
            match pool {
                Some(pool) => {
                    // concurrent launch stage: route each launch through
                    // the placement table to the least-loaded replica of
                    // its group (legacy group-hash when unplaced)
                    for l in launches {
                        let group = jit
                            .window
                            .get(l.pack.ops[0])
                            .map(|op| op.group)
                            .unwrap_or(0);
                        let worker = match &placed {
                            Some((_, table, _)) => {
                                let loads: Vec<f64> = (0..pool.workers())
                                    .map(|w| pool.in_flight_of(w) as f64)
                                    .collect();
                                table.route(group, &loads)
                            }
                            None => group as usize % pool.workers(),
                        };
                        // re-price on the routed class (a slow replica is
                        // not a straggler) and book the worker's backlog
                        let est_routed = match &placed {
                            Some((topo, _, _)) => {
                                jit.executor().estimate_group_on_class_us(
                                    group,
                                    topo.class_of(worker),
                                    l.pack.ops.len() as u32,
                                )
                            }
                            None => l.est_us,
                        };
                        jit.reprice_pending(l.ticket, est_routed);
                        if let Some(b) = worker_backlog.get_mut(worker) {
                            *b += est_routed;
                        }
                        ticket_route.insert(l.ticket, (worker, group, est_routed));
                        let model = slots[group as usize].name.clone();
                        let rows: Vec<Vec<f32>> = jit
                            .payloads_of(&l.pack.ops)
                            .into_iter()
                            .cloned()
                            .collect();
                        let res_tx = res_tx.clone();
                        let ticket = l.ticket;
                        pool.submit_to(worker, move |backend: &mut B| {
                            let r = backend
                                .execute(&model, &rows)
                                .map_err(|e| e.to_string());
                            let _ = res_tx.send((ticket, r));
                        });
                    }
                }
                None => {
                    // inline execution on the driver thread
                    for l in launches {
                        let run = jit.run_issued(l.ticket);
                        let done = jit.finish_launch(l.ticket, wall_us(t0), run);
                        for c in &done {
                            record_completion(&mut metrics, c);
                        }
                    }
                }
            }
            // 3. fold in finished pool launches (block briefly when the
            // arrival channel is gone and only results remain — avoids a
            // busy spin on the disconnected arrival channel)
            let mut results: Vec<(u64, std::result::Result<ModelExec, String>)> =
                Vec::new();
            if disconnected && jit.inflight_launches() > 0 {
                if let Ok(r) = res_rx.recv_timeout(Duration::from_micros(500)) {
                    results.push(r);
                }
            }
            while let Ok(r) = res_rx.try_recv() {
                results.push(r);
            }
            view_dirty |= !results.is_empty();
            for (ticket, result) in results {
                let (worker, group, booked_est) =
                    ticket_route.remove(&ticket).unwrap_or((0, 0, 0.0));
                if let Some(b) = worker_backlog.get_mut(worker) {
                    *b = (*b - booked_est).max(0.0);
                }
                let mut run = match result {
                    Ok(exec) => PackRun {
                        duration_us: exec.duration_us,
                        executed: exec.batch,
                        ok: true,
                        device_class: 0,
                    },
                    Err(e) => {
                        crate::util::logging::emit(
                            crate::util::logging::Level::Error,
                            format_args!("pooled execute failed: {e}"),
                        );
                        PackRun {
                            duration_us: 0.0,
                            executed: 0,
                            ok: false,
                            device_class: 0,
                        }
                    }
                };
                if let Some((topo, _, _)) = &placed {
                    run.device_class = topo.class_of(worker);
                }
                let (ok, dur) = (run.ok, run.duration_us);
                let done = jit.finish_launch(ticket, wall_us(t0), run);
                for c in &done {
                    record_completion(&mut metrics, c);
                }
                if ok {
                    if let Some((topo, _, rebal)) = placed.as_mut() {
                        if report_devices {
                            metrics.device_launch(
                                worker,
                                topo.spec_of(worker).name,
                                dur,
                            );
                        }
                        if let Some(rb) = rebal.as_mut() {
                            rb.observe_launch(group, worker, dur);
                        }
                    }
                }
            }
            for l in jit.take_launches() {
                if l.ok {
                    metrics.launch(&l);
                }
            }
            // rebalance between windows (wall clock); keep the estimator's
            // primary device class in step with the table's primaries
            if let Some((topo, table, rebal)) = placed.as_mut() {
                if let Some(rb) = rebal.as_mut() {
                    let actions = rb.maybe_rebalance(wall_us(t0), table, topo);
                    if !actions.is_empty() {
                        repin_group_classes(jit.executor_mut(), table, topo, groups);
                        // replicas/classes moved: estimates and routing
                        // inputs changed under the last snapshot
                        view_dirty = true;
                    }
                    metrics.replications = rb.stats.replications;
                    metrics.migrations = rb.stats.migrations;
                }
            }
            // publish a fresh admission snapshot for the frontend stage —
            // after this iteration's submits, launches and completions,
            // so the view only ever lags reality, never leads it. Skipped
            // on idle ticks (state unchanged => the last view is still
            // exact; the in-flight term only ages conservatively), with a
            // heartbeat re-publish so healthy-idle never reads as stale.
            if let Some((_, cell, _)) = &fe {
                let heartbeat =
                    last_publish.elapsed().as_secs_f64() * 1e6 > STALE_VIEW_US / 2.0;
                if view_dirty || heartbeat {
                    view_seq += 1;
                    cell.publish(build_view(
                        view_seq,
                        &jit,
                        &placed,
                        pool_workers,
                        &worker_backlog,
                        (&drained, &drained_by_stream),
                    ));
                    view_dirty = false;
                    last_publish = Instant::now();
                }
            }
            if disconnected && jit.window.is_empty() && jit.inflight_launches() == 0 {
                break;
            }
        }
        gen.join().expect("generator thread");
        if let Some((acc_rx, _, stage)) = fe {
            // the frontend exits once the generator's intake disconnects
            // and it has drained; fold its thread-local accounting in
            drop(acc_rx);
            metrics.merge_frontend(&stage.join());
        }
        metrics.span_us = wall_us(t0);
        metrics.jit = jit.stats.clone();
        ServeReport {
            metrics,
            policy: policy_name,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{ArrivalKind, Request, TenantSpec, Trace};

    /// The deterministic simulator backend (now public as [`SimBackend`]):
    /// fixed per-launch overhead + per-row cost, pow2 padded variants.
    fn sim() -> SimBackend {
        SimBackend::default()
    }

    fn tenants(n: u32, rate: f64, slo_us: u64) -> Vec<TenantSpec> {
        (0..n)
            .map(|i| TenantSpec::new(i, "m", slo_us, rate, ArrivalKind::Poisson))
            .collect()
    }

    #[test]
    fn coalescing_batches_more_than_fifo() {
        let trace = Trace::generate(&tenants(8, 200.0, 100_000), 50, 42);
        let mut fifo = Server::new(sim(), BatchPolicy::NoBatching);
        let r1 = fifo.replay(&trace);
        let mut coal = Server::new(sim(), BatchPolicy::coalescing());
        let r2 = coal.replay(&trace);
        assert!(r2.metrics.mean_occupancy() > 2.0 * r1.metrics.mean_occupancy());
        assert!(r2.metrics.batches < r1.metrics.batches);
        // all requests accounted for in both
        assert_eq!(r1.metrics.total_completed(), 400);
        assert_eq!(r2.metrics.total_completed(), 400);
    }

    #[test]
    fn coalescing_improves_slo_under_load() {
        // 8 tenants at high rate: FIFO's serialization blows deadlines,
        // coalescing amortizes the fixed cost
        let trace = Trace::generate(&tenants(8, 400.0, 30_000), 80, 7);
        let mut fifo = Server::new(sim(), BatchPolicy::NoBatching);
        let a1 = fifo.replay(&trace).metrics.overall_attainment();
        let mut coal = Server::new(sim(), BatchPolicy::coalescing());
        let a2 = coal.replay(&trace).metrics.overall_attainment();
        assert!(a2 > a1, "coalescing {a2} must beat fifo {a1}");
        assert!(a2 > 0.9, "coalescing attainment {a2}");
    }

    #[test]
    fn light_load_latency_stays_low() {
        let trace = Trace::generate(&tenants(2, 20.0, 100_000), 30, 3);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.replay(&trace);
        assert_eq!(r.metrics.overall_attainment(), 1.0);
        // nobody waits longer than window + exec
        for t in r.metrics.tenants.values() {
            assert!(t.latency.max_us() < 3_000.0 + 500.0 + 50.0 * 16.0 + 1_000.0);
        }
    }

    #[test]
    fn tight_slo_forces_early_launch() {
        // single tenant, huge window, but SLO 2ms: the safety margin must
        // launch well before the 50ms window
        let trace = Trace::generate(&tenants(1, 100.0, 2_000), 20, 9);
        let mut s = Server::new(
            sim(),
            BatchPolicy::Coalescing {
                window_us: 50_000.0,
                target_batch: 16,
                safety_margin_us: 200.0,
            },
        );
        let r = s.replay(&trace);
        assert!(
            r.metrics.overall_attainment() > 0.8,
            "attainment {}",
            r.metrics.overall_attainment()
        );
    }

    #[test]
    fn overload_drops_via_admission() {
        let trace = Trace::generate(&tenants(4, 5_000.0, 1_000), 400, 5);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        s.admission = Admission::new(32);
        let r = s.replay(&trace);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert!(drops > 0, "overload must shed load");
        // completed + dropped == offered
        assert_eq!(r.metrics.total_completed() + drops, 1600);
    }

    #[test]
    fn no_batching_runs_batch_one() {
        let trace = Trace::generate(&tenants(4, 100.0, 100_000), 20, 21);
        let mut s = Server::new(sim(), BatchPolicy::NoBatching);
        let r = s.replay(&trace);
        assert_eq!(r.metrics.total_completed(), 80);
        assert_eq!(r.metrics.mean_occupancy(), 1.0);
        assert_eq!(r.metrics.jit.mean_pack(), 1.0);
    }

    #[test]
    fn replay_is_deterministic_through_unified_core() {
        // two identical traces through the unified core must produce
        // identical metrics (deterministic backend => deterministic
        // schedule, bit-for-bit)
        let trace = Trace::generate(&tenants(4, 150.0, 50_000), 40, 13);
        let run = || {
            let mut s = Server::new(sim(), BatchPolicy::coalescing());
            s.replay(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        assert_eq!(a.metrics.batches, b.metrics.batches);
        assert_eq!(a.metrics.useful_rows, b.metrics.useful_rows);
        assert_eq!(a.metrics.padded_rows, b.metrics.padded_rows);
        assert_eq!(a.metrics.span_us.to_bits(), b.metrics.span_us.to_bits());
        assert_eq!(a.metrics.busy_us.to_bits(), b.metrics.busy_us.to_bits());
        assert_eq!(a.metrics.jit.launches, b.metrics.jit.launches);
        assert_eq!(a.metrics.jit.slo_hits, b.metrics.jit.slo_hits);
        for (ta, tb) in a.metrics.tenants.iter().zip(b.metrics.tenants.iter()) {
            assert_eq!(ta.0, tb.0);
            assert_eq!(ta.1.slo_hits, tb.1.slo_hits);
            assert_eq!(ta.1.slo_misses, tb.1.slo_misses);
            assert_eq!(ta.1.dropped, tb.1.dropped);
            assert_eq!(
                ta.1.latency.quantile_us(0.99).to_bits(),
                tb.1.latency.quantile_us(0.99).to_bits()
            );
        }
    }

    #[test]
    fn jit_pack_stats_surface_in_metrics() {
        let trace = Trace::generate(&tenants(6, 300.0, 100_000), 30, 17);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.replay(&trace);
        assert!(r.metrics.jit.launches > 0);
        assert!(r.metrics.jit.mean_pack() > 1.0, "packing must happen");
        let eff = r.metrics.jit.pack_efficiency();
        assert!(eff > 0.0 && eff <= 1.0 + 1e-9, "eff={eff}");
        assert!(r.render().contains("jit:"), "report shows jit stats");
    }

    fn burst_trace(n: usize, gap_us: f64, slo_us: u64) -> Trace {
        let requests = (0..n)
            .map(|i| Request {
                id: i as u64,
                tenant: 0,
                model: "m".to_string(),
                arrival_us: i as f64 * gap_us,
                deadline_us: i as f64 * gap_us + slo_us as f64,
            })
            .collect();
        Trace {
            requests,
            tenants: vec![TenantSpec::new(0, "m", slo_us, 1_000.0, ArrivalKind::Poisson)],
        }
    }

    #[test]
    fn single_tenant_burst_coalesces_at_no_attainment_cost() {
        // the tentpole acceptance: 8 requests from ONE (tenant, model)
        // stream, 50µs apart. Under the independence contract the burst
        // rides multi-problem packs; with program order binding (the
        // pre-change behavior, still available via `independent_streams`)
        // the same burst serializes into singleton launches and loses SLOs.
        let trace = burst_trace(8, 50.0, 3_000);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r_ind = s.replay(&trace);
        let mut s_dep = Server::new(sim(), BatchPolicy::coalescing());
        s_dep.independent_streams = false;
        let r_dep = s_dep.replay(&trace);
        assert!(
            r_ind.metrics.jit.mean_pack() > 1.5,
            "burst must coalesce, mean_pack {}",
            r_ind.metrics.jit.mean_pack()
        );
        assert_eq!(
            r_dep.metrics.jit.mean_pack(),
            1.0,
            "dependent stream keeps one op per launch"
        );
        assert!(
            r_ind.metrics.overall_attainment() >= r_dep.metrics.overall_attainment(),
            "coalescing may never lose attainment: {} vs {}",
            r_ind.metrics.overall_attainment(),
            r_dep.metrics.overall_attainment()
        );
        assert_eq!(r_ind.metrics.total_completed(), 8);
        assert!(r_ind.metrics.same_stream_rows > 0, "burst shares launches");
        assert_eq!(r_dep.metrics.same_stream_rows, 0);
        // conservation in the dependent run too (late burst members may be
        // shed by the per-op drain pricing — they were doomed anyway)
        let dep_drops: u64 = r_dep.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r_dep.metrics.total_completed() + dep_drops, 8);
    }

    #[test]
    fn dependent_stream_admission_prices_per_op_drain() {
        // with program order binding a queued stream drains one op per
        // launch — pricing it at the pack cap (one padded batch) would
        // re-open the doomed-admission hole for stateful streams
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::coalescing().jit_config(&slots, 64); // cap 16
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for _ in 0..4 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: 0, // ONE dependent stream
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: false,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        assert_eq!(jit.window.pending_in_group(0), 4);
        // true drain is 5 singleton launches (2750µs), not one padded
        // batch (900µs): a 1500µs deadline must be shed
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 0,
                arrival_us: 0.0,
                deadline_us: 1_500.0,
                independent: false,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "doomed dependent request is shed");
    }

    #[test]
    fn dependent_multi_stream_queue_prices_cross_stream_packing() {
        // 8 DISTINCT dependent streams with one op each drain in about one
        // cap-wide launch — admission must not price them as 8 serial
        // launches and shed an easily-servable 9th request
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::coalescing().jit_config(&slots, 64); // cap 16
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for t in 0..8 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: t, // eight different streams
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: false,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        assert_eq!(jit.window.pending_in_group(0), 8);
        // all 9 ops are stream heads, so the drain is ONE 9-wide launch
        // (padded 16) ≈ 1300µs — well inside a 2.5ms deadline (a naive
        // one-launch-per-op price of 9·550µs = 4950µs would shed it)
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 9,
                arrival_us: 0.0,
                deadline_us: 2_500.0,
                independent: false,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 0, "servable multi-stream dependent load admitted");
        assert_eq!(jit.window.pending_in_group(0), 9);
    }

    #[test]
    fn admission_prices_inflight_drain() {
        // satellite bugfix: a request that survives queue-only pricing but
        // is doomed behind the group's in-flight launches must be shed
        // (the pooled/async drive mode's systematic under-estimate)
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let policy = BatchPolicy::Coalescing {
            window_us: 0.0,
            target_batch: 1,
            safety_margin_us: 0.0,
        };
        let cfg = policy.jit_config(&slots, 64);
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for t in 0..4 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: t,
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: true,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        let (launches, _) = jit.issue_ready();
        assert!(!launches.is_empty());
        assert_eq!(jit.window.inflight_in_group(0), 4, "work is on the device");
        assert_eq!(jit.window.pending_in_group(0), 0);
        // a doomed request into an EMPTY queue still runs, in-flight work
        // notwithstanding (the documented escape hatch: launches already
        // on the device cannot be delayed by a late newcomer, so the
        // client gets a late answer rather than none) — this is the
        // contract `decide`'s old `depth + inflight` argument broke
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 8,
                arrival_us: 0.0,
                deadline_us: 600.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 0, "empty-queue escape hatch fires despite in-flight");
        assert_eq!(jit.window.pending_in_group(0), 1);
        // now real work is queued: a doomed request is shed, and its doom
        // comes from the in-flight term — queue-only pricing is 600µs
        // (fixed 500 + 2·50/row) but the pending batch-4 launch's own
        // scheduler estimate adds 700µs, so a 1000µs deadline is hopeless
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 9,
                arrival_us: 0.0,
                deadline_us: 1_000.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "doomed request behind in-flight work is shed");
        assert_eq!(jit.window.pending_in_group(0), 1, "it was never submitted");
        // enough slack to survive the full (queue + in-flight) drain
        // (600µs queue + 700µs in flight = 1300µs): admitted
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 10,
                arrival_us: 0.0,
                deadline_us: 2_000.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        assert_eq!(jit.window.pending_in_group(0), 2);
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "no new drop");
    }

    #[test]
    fn admission_prices_each_inflight_launch_separately() {
        // several small in-flight launches each pay their fixed per-launch
        // overhead: 4 singleton launches drain in 4·550µs = 2200µs, NOT the
        // 700µs one batch-4 launch would take — pricing them as one batch
        // (the naive estimate) would re-open the doomed-admission hole
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::NoBatching.jit_config(&slots, 64); // singleton packs
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for t in 0..4 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: t,
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: true,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        let (launches, _) = jit.issue_ready();
        assert_eq!(launches.len(), 4, "NoBatching issues singletons");
        assert!((jit.inflight_group_est_us(0, 1) - 2_200.0).abs() < 1e-9);
        // queue one request with slack to spare (2200 in flight + 550 own
        // launch < 1e9) so the doomed-shed hatch applies to what follows
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 8,
                arrival_us: 0.0,
                deadline_us: 1e9,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        assert_eq!(jit.window.pending_in_group(0), 1);
        // deadline 2500µs would survive one-batch in-flight pricing (700
        // + 1100 queue) but not the true per-launch drain (2200 + 1100):
        // 4 singleton launches each pay their fixed overhead
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 9,
                arrival_us: 0.0,
                deadline_us: 2_500.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "doomed behind four singleton launches");
        // a deadline past the full per-launch drain is still admitted
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 10,
                arrival_us: 0.0,
                deadline_us: 4_000.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        assert_eq!(jit.window.pending_in_group(0), 2);
    }

    #[test]
    fn admission_prices_queue_deeper_than_one_pack_per_launch() {
        // the un-issued queue drains in ceil(queued/pack_cap) launches, not
        // one padded batch: under NoBatching (pack cap 1), 4 queued
        // singletons + this request cost 5·550µs = 2750µs, not the 900µs a
        // single padded batch-8 estimate would claim
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::NoBatching.jit_config(&slots, 64);
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for t in 0..4 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: t,
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: true,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        // nothing issued: all four wait in the un-issued queue
        assert_eq!(jit.window.pending_in_group(0), 4);
        assert_eq!(jit.window.inflight_in_group(0), 0);
        // deadline 1500µs survives one-padded-batch pricing (900µs) but
        // not the true per-launch queue drain (2750µs)
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 9,
                arrival_us: 0.0,
                deadline_us: 1_500.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "doomed behind a deep singleton queue");
        // past the full drain it is admitted
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 10,
                arrival_us: 0.0,
                deadline_us: 3_000.0,
                independent: true,
                parallelism: 1.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        assert_eq!(jit.window.pending_in_group(0), 5);
    }

    #[test]
    fn per_device_class_ewmas_are_isolated() {
        // the worker-aware-estimates contract: a t4 (class 1) observation
        // must never update the v100 (class 0) estimate, and vice versa
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let mut ex = ServeExecutor::new(&mut backend, slots);
        ex.set_class_speeds(vec![1.0, 0.5]);
        let prior_v100 = ex.estimate_group_on_class_us(0, 0, 4);
        let prior_t4 = ex.estimate_group_on_class_us(0, 1, 4);
        // unlearned estimates fall back to the backend prior scaled by the
        // class's relative speed: the t4 prior is 2x the v100 prior
        assert!((prior_t4 - prior_v100 * 2.0).abs() < 1e-9);
        // a t4 observation lands in the t4 slot only
        ex.observe_group(1, 0, 4, 9_999.0);
        assert_eq!(
            ex.estimate_group_on_class_us(0, 0, 4),
            prior_v100,
            "t4 observation must not touch the v100 estimate"
        );
        assert_eq!(ex.estimate_group_on_class_us(0, 1, 4), 9_999.0);
        // and a v100 observation leaves the learned t4 estimate alone
        ex.observe_group(0, 0, 4, 123.0);
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 4), 123.0);
        assert_eq!(ex.estimate_group_on_class_us(0, 1, 4), 9_999.0);
        // the group's primary class picks which estimate admission sees
        assert_eq!(ex.estimate_group_us(0, 4), 123.0, "default class 0");
        ex.set_group_class(0, 1);
        assert_eq!(ex.estimate_group_us(0, 4), 9_999.0);
    }

    /// A fleet-saturating two-model workload: `hot` overloads one v100,
    /// `cold` idles along — the rebalancer's bread and butter.
    fn skewed_trace(per_tenant: usize) -> Trace {
        let tenants = vec![
            TenantSpec::new(0, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(3, "cold", 30_000, 300.0, ArrivalKind::Poisson),
        ];
        Trace::generate(&tenants, per_tenant, 71)
    }

    fn heavy_sim() -> SimBackend {
        // per-row cost high enough that 6000 hot rows/s overload a single
        // v100-speed worker (batch-8 launch = 1800µs -> ~4400 rows/s)
        SimBackend {
            fixed_us: 200.0,
            per_row_us: 200.0,
            max_b: 8,
            d_in: 4,
        }
    }

    #[test]
    fn replay_placed_replicates_hot_group_and_beats_static_placement() {
        let trace = skewed_trace(400);
        let offered = trace.requests.len() as u64;
        let topo = DeviceTopology::from_names(&["v100".into(), "t4".into()]).unwrap();
        let rb_cfg = RebalanceConfig {
            window_us: 25_000.0,
            ..RebalanceConfig::default()
        };
        // dynamic: rebalancer enabled
        let mut dynamic = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (dyn_report, table) = dynamic.replay_placed(&trace, &topo, Some(rb_cfg));
        // static: the same initial placement, pinned for the whole run
        let mut pinned = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (static_report, _) = pinned.replay_placed(&trace, &topo, None);

        // groups are sorted by model name: cold = 0, hot = 1
        assert!(
            dyn_report.metrics.replications >= 1,
            "the hot group must replicate: {:?}",
            dyn_report.metrics
        );
        assert!(
            table.replicas_of(1).len() >= 2,
            "hot group on both devices: {:?}",
            table.replicas_of(1)
        );
        // both devices pull hot load after replication
        assert_eq!(dyn_report.metrics.devices.len(), 2);
        assert!(dyn_report.metrics.devices[0].busy_us > 0.0);
        assert!(dyn_report.metrics.devices[1].busy_us > 0.0);
        // conservation in both runs
        for r in [&dyn_report, &static_report] {
            let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
            assert_eq!(r.metrics.total_completed() + drops, offered);
        }
        // the acceptance bar: replication buys aggregate throughput at no
        // worse SLO attainment than the pinned placement
        assert!(
            dyn_report.metrics.throughput() > static_report.metrics.throughput(),
            "dynamic {:.0}/s must beat static {:.0}/s",
            dyn_report.metrics.throughput(),
            static_report.metrics.throughput()
        );
        assert!(
            dyn_report.metrics.overall_attainment()
                >= static_report.metrics.overall_attainment(),
            "attainment may not regress: {:.3} vs {:.3}",
            dyn_report.metrics.overall_attainment(),
            static_report.metrics.overall_attainment()
        );
    }

    #[test]
    fn slow_replica_launches_are_not_false_evictions() {
        // v100 + k80: the speed ratio (~4x) exceeds the 3x eviction
        // factor, so once the hot group replicates onto the k80 its
        // k80-routed launches run ~4x the primary-class estimate. The
        // launch estimate is re-priced on the routed class at issue — a
        // slow replica running at its own speed is not a straggler.
        let tenants = vec![
            TenantSpec::new(0, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(3, "cold", 30_000, 150.0, ArrivalKind::Poisson),
        ];
        let trace = Trace::generate(&tenants, 300, 29);
        let topo = DeviceTopology::from_names(&["v100".into(), "k80".into()]).unwrap();
        let mut s = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (r, table) = s.replay_placed(
            &trace,
            &topo,
            Some(RebalanceConfig {
                window_us: 25_000.0,
                ..RebalanceConfig::default()
            }),
        );
        assert!(
            r.metrics.replications >= 1,
            "hot group must replicate onto the k80"
        );
        assert!(table.replicas_of(1).len() >= 2);
        assert_eq!(
            r.metrics.jit.evictions, 0,
            "slow-replica launches must not count as stragglers"
        );
    }

    #[test]
    fn replay_placed_single_worker_conserves_and_reports_devices() {
        let trace = Trace::generate(&tenants(4, 150.0, 100_000), 30, 19);
        let topo = DeviceTopology::from_names(&["v100".into()]).unwrap();
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let (r, table) = s.replay_placed(&trace, &topo, None);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 120);
        assert_eq!(r.metrics.devices.len(), 1);
        assert_eq!(r.metrics.devices[0].name, "v100");
        assert!(r.metrics.devices[0].launches > 0);
        assert!(table.is_total(1, 1), "single group on the single worker");
        assert!(r.render().contains("device 0 (v100)"));
    }

    #[test]
    fn replay_placed_is_deterministic() {
        let trace = skewed_trace(120);
        let topo = DeviceTopology::from_names(&["v100".into(), "t4".into()]).unwrap();
        let run = || {
            let mut s = Server::new(heavy_sim(), BatchPolicy::coalescing());
            let (r, _) = s.replay_placed(
                &trace,
                &topo,
                Some(RebalanceConfig {
                    window_us: 25_000.0,
                    ..RebalanceConfig::default()
                }),
            );
            r
        };
        let a = run();
        let b = run();
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        assert_eq!(a.metrics.batches, b.metrics.batches);
        assert_eq!(a.metrics.span_us.to_bits(), b.metrics.span_us.to_bits());
        assert_eq!(a.metrics.replications, b.metrics.replications);
        assert_eq!(a.metrics.migrations, b.metrics.migrations);
        for (da, db) in a.metrics.devices.iter().zip(b.metrics.devices.iter()) {
            assert_eq!(da.launches, db.launches);
            assert_eq!(da.busy_us.to_bits(), db.busy_us.to_bits());
        }
    }

    #[test]
    fn admission_divides_drain_across_replicas() {
        // 4 queued singletons at NoBatching drain in 5 launches = 2750µs
        // on one worker; on two replicas the same queue is priced at half,
        // so a 1500µs deadline that a single worker must shed is admitted
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::NoBatching.jit_config(&slots, 64);
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        for t in 0..4 {
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant: t,
                    arrival_us: 0.0,
                    deadline_us: 1e9,
                    independent: true,
                    parallelism: 1.0,
                    device_backlog_us: None,
                    row: vec![0.0; 4],
                },
            );
        }
        assert_eq!(jit.window.pending_in_group(0), 4);
        // two replicas: drain 2750/2 = 1375µs < 1500µs deadline -> admit
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 9,
                arrival_us: 0.0,
                deadline_us: 1_500.0,
                independent: true,
                parallelism: 2.0,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 0, "two-replica drain fits the deadline");
        assert_eq!(jit.window.pending_in_group(0), 5);
        // heterogeneous replicas are speed-weighted, not counted: a v100
        // primary plus a k80 replica is ~1.25 workers — the queue of 6
        // drains in 6·550/1.25 = 2640µs, so the same 1500µs deadline that
        // two FULL replicas could serve must be shed
        Server::<SimBackend>::admit_request(
            &mut jit,
            &mut streams,
            &admission,
            &mut metrics,
            &slots,
            AdmitReq {
                group: 0,
                tenant: 10,
                arrival_us: 0.0,
                deadline_us: 1_500.0,
                independent: true,
                parallelism: 1.25,
                device_backlog_us: None,
                row: vec![0.0; 4],
            },
        );
        let drops: u64 = metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(drops, 1, "slow replica must not count as a full worker");
        assert_eq!(jit.window.pending_in_group(0), 5);
    }

    #[test]
    fn pooled_paths_agree_on_admission_inputs() {
        // regression: on a single-worker fleet the placement-routed and
        // legacy hash-routed launch stages must feed the gate identical
        // (parallelism, backlog) inputs — so the two paths admit
        // identically on the same trace
        let topo = DeviceTopology::homogeneous(1, DeviceSpec::v100());
        let costs: Vec<(u64, f64)> = (0..3).map(|g| (g, 1.0)).collect();
        let table = Placer::place(&costs, &topo);
        let placed: PlacedState = Some((topo, table, None));
        let backlog = vec![1_234.0];
        for g in 0..3u64 {
            assert_eq!(
                gate_inputs(&placed, 1, &backlog, g),
                gate_inputs(&None, 1, &backlog, g),
                "group {g}"
            );
        }
    }

    #[test]
    fn unplaced_pooled_backlog_feeds_the_gate() {
        // satellite bugfix: the legacy hash-routed pool books est_routed
        // into worker_backlog at launch, so admission must consult the
        // hash-routed worker's entry instead of flying queue-blind.
        // NOTE: every public pooled driver builds a placement table, so
        // this configuration (pool without placement) is reachable only
        // through `realtime_loop`'s internal signature — the test pins
        // the internal contract so the legacy fallback arms in
        // `gate_inputs` and the launch router cannot drift apart.
        let backlog = vec![5_000.0, 0.0];
        assert_eq!(gate_inputs(&None, 2, &backlog, 0), (1.0, Some(5_000.0)));
        assert_eq!(gate_inputs(&None, 2, &backlog, 1), (1.0, Some(0.0)));
        assert_eq!(gate_inputs(&None, 2, &backlog, 2), (1.0, Some(5_000.0)));
        // no pool at all: nothing measured, the JIT in-flight term prices
        assert_eq!(gate_inputs(&None, 0, &backlog, 0), (1.0, None));

        // and the booked backlog actually reaches the shed decision: 5ms
        // on the routed worker dooms a 2ms deadline that the same gate
        // admits when the worker is free
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let cfg = BatchPolicy::coalescing().jit_config(&slots, 64);
        let mut jit: JitCompiler<ServeExecutor<&mut SimBackend>, Vec<f32>> =
            JitCompiler::with_payloads(
                cfg,
                ServeExecutor::new(&mut backend, slots.clone()),
            );
        let admission = Admission::default();
        let mut metrics = ServeMetrics::default();
        let mut streams: BTreeMap<(u32, u64), u32> = BTreeMap::new();
        // one queued request so the doomed-shed hatch applies
        for (tenant, deadline, booked) in
            [(0u32, 1e9, 0.0), (1, 2_000.0, 5_000.0), (2, 2_000.0, 0.0)]
        {
            let (parallelism, backlog) =
                gate_inputs(&None, 2, &[booked, 0.0], 0);
            Server::<SimBackend>::admit_request(
                &mut jit,
                &mut streams,
                &admission,
                &mut metrics,
                &slots,
                AdmitReq {
                    group: 0,
                    tenant,
                    arrival_us: 0.0,
                    deadline_us: deadline,
                    independent: true,
                    parallelism,
                    device_backlog_us: backlog,
                    row: vec![0.0; 4],
                },
            );
        }
        assert_eq!(
            metrics.tenants.get(&1).map(|t| t.dropped),
            Some(1),
            "booked backlog must shed the doomed request"
        );
        assert_eq!(jit.window.pending_in_group(0), 2, "tenants 0 and 2 admitted");
    }

    /// Backend that wedges the calling thread for a fixed stall per
    /// execute — simulates the scheduler thread being stuck mid-iteration
    /// (inline launch mode executes on the scheduler thread).
    struct StallingBackend {
        inner: SimBackend,
        stall: Duration,
    }

    impl ModelBackend for StallingBackend {
        fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
            std::thread::sleep(self.stall);
            self.inner.execute(model, rows)
        }

        fn estimate_us(&self, model: &str, n: u32) -> f64 {
            self.inner.estimate_us(model, n)
        }

        fn max_batch(&self, model: &str) -> u32 {
            self.inner.max_batch(model)
        }

        fn d_in(&self, model: &str) -> usize {
            self.inner.d_in(model)
        }

        fn padded_batch(&self, model: &str, n: u32) -> u32 {
            self.inner.padded_batch(model, n)
        }
    }

    #[test]
    fn frontend_admission_latency_bounded_under_scheduler_stall() {
        // the tentpole acceptance: with the scheduler thread stalled 10ms
        // mid-iteration (every inline execute sleeps), frontend admission
        // p99 stays under 1ms — decisions ride the published snapshot,
        // never the scheduler thread. 120 samples so the p99 tolerates a
        // single OS-scheduling outlier on loaded CI machines.
        let trace = burst_trace(120, 300.0, 1_000_000); // 1s SLO: none doomed
        let mut s = Server::new(
            StallingBackend {
                inner: sim(),
                stall: Duration::from_millis(10),
            },
            BatchPolicy::coalescing(),
        );
        let r = s.run_realtime(&trace, 1.0);
        assert_eq!(
            r.metrics.admission_decisions, 120,
            "every request gets a frontend decision"
        );
        let p99 = r.metrics.admission_latency.quantile_us(0.99);
        assert!(
            p99 < 1_000.0,
            "frontend admission p99 {p99}µs must not wait on the scheduler"
        );
        assert!(
            r.metrics.stale_decisions > 0,
            "stalled iterations must surface as stale-view decisions"
        );
        // conservation through the frontend path
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 120);

        // contrast: the synchronous gate decides between channel drains,
        // so its admission latency eats the stalls
        let mut s2 = Server::new(
            StallingBackend {
                inner: sim(),
                stall: Duration::from_millis(10),
            },
            BatchPolicy::coalescing(),
        );
        s2.frontend = false;
        let r2 = s2.run_realtime(&trace, 1.0);
        let sync_p99 = r2.metrics.admission_latency.quantile_us(0.99);
        assert!(
            sync_p99 > p99,
            "sync gate p99 {sync_p99}µs must show the stall the frontend {p99}µs hides"
        );
    }

    #[test]
    fn realtime_mode_serves_everything() {
        let trace = Trace::generate(&tenants(3, 300.0, 200_000), 10, 11);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.run_realtime(&trace, 50.0); // 50x compressed
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 30);
        assert!(r.metrics.span_us > 0.0);
        assert!(r.metrics.jit.launches > 0, "served through the JIT core");
        // the frontend stage (default-on) decided every request
        assert_eq!(r.metrics.admission_decisions, 30);
        assert!(r.metrics.frontend_wait.count() > 0, "channel wait recorded");
    }

    #[test]
    fn realtime_sync_gate_still_serves() {
        // the pre-frontend path stays available (and measured): decisions
        // happen at drain time, so latency == channel wait
        let trace = Trace::generate(&tenants(2, 200.0, 200_000), 8, 31);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        s.frontend = false;
        let r = s.run_realtime(&trace, 50.0);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 16);
        assert_eq!(r.metrics.admission_decisions, 16);
        assert_eq!(
            r.metrics.admission_latency.count(),
            r.metrics.frontend_wait.count(),
            "sync gate records decision latency and channel wait together"
        );
        assert_eq!(r.metrics.stale_decisions, 0, "no snapshots on the sync path");
    }

    #[test]
    fn realtime_pooled_serves_two_models_concurrently() {
        // two models → two coalescing groups → two pool workers, each
        // owning its own backend; every request completes or is shed
        let tenants = vec![
            TenantSpec::new(0, "alpha", 200_000, 300.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "beta", 200_000, 300.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "alpha", 200_000, 300.0, ArrivalKind::Poisson),
        ];
        let trace = Trace::generate(&tenants, 10, 23);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.run_realtime_pooled(&trace, 50.0, 2, |_| sim());
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 30);
        assert!(r.metrics.jit.launches > 0);
        assert!(r.metrics.batches > 0);
    }
}
