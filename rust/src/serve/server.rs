//! The serving loop: declarative requests in, coalesced batches out.
//!
//! Two drive modes share one batching core:
//!
//! * [`Server::replay`] — virtual-paced: arrivals advance a virtual clock,
//!   service times are *real measured executions* (PJRT). Deterministic
//!   given a trace; used by benches and the e2e example.
//! * [`Server::run_realtime`] — threaded: per-tenant generator threads
//!   pace arrivals on the wall clock and a batcher thread drains them;
//!   latencies are wall-clock. Used by `vliwd serve`.
//!
//! The batching rule is the model-level instance of the paper's scheduler:
//! EDF across queues, bounded coalescing window, pad-up to the smallest
//! compiled batch variant, launch early when a deadline approaches.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::runtime::executor::{ModelExec, PjrtExecutor};
use crate::runtime::golden;
use crate::serve::admission::{Admission, Admit};
use crate::serve::metrics::ServeMetrics;
use crate::workload::trace::Trace;
use crate::Result;

/// Batching policy.
#[derive(Debug, Clone)]
pub enum BatchPolicy {
    /// Batch-1 FIFO (the early-binding baseline).
    NoBatching,
    /// SLO-aware coalescing (the paper's approach).
    Coalescing {
        /// Max hold time for the oldest queued request, µs.
        window_us: f64,
        /// Launch as soon as this many requests are queued.
        target_batch: u32,
        /// Slack reserve before a deadline forces a launch, µs.
        safety_margin_us: f64,
    },
}

impl BatchPolicy {
    /// Default coalescing parameters.
    pub fn coalescing() -> Self {
        BatchPolicy::Coalescing {
            window_us: 3_000.0,
            target_batch: 8,
            safety_margin_us: 1_000.0,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::NoBatching => "batch1-fifo",
            BatchPolicy::Coalescing { .. } => "ooo-coalescing",
        }
    }
}

/// Backend abstraction (real PJRT or a test stub).
pub trait ModelBackend {
    /// Execute a batch of rows on a model.
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec>;
    /// Estimated service time for a batch of `n`, µs.
    fn estimate_us(&mut self, model: &str, n: u32) -> f64;
    /// Largest compiled batch.
    fn max_batch(&self, model: &str) -> u32;
    /// Input feature count.
    fn d_in(&self, model: &str) -> usize;
}

impl ModelBackend for PjrtExecutor {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        PjrtExecutor::execute_model(self, model, rows)
    }

    fn estimate_us(&mut self, model: &str, n: u32) -> f64 {
        // flops-proportional prior scaled by the learned model rate; use
        // per-query flops × padded batch
        let (flops, _) = match self.manifest().model(model) {
            Ok(e) => (e.flops_per_query as f64, e.d_in),
            Err(_) => return 1_000.0,
        };
        let batch = n.max(1) as f64;
        flops * batch / (self.prior_gflops * 1e3)
    }

    fn max_batch(&self, model: &str) -> u32 {
        self.manifest()
            .model(model)
            .map(|e| e.max_batch())
            .unwrap_or(1)
    }

    fn d_in(&self, model: &str) -> usize {
        self.manifest()
            .model(model)
            .map(|e| e.d_in as usize)
            .unwrap_or(0)
    }
}

#[derive(Debug, Clone)]
struct Pending {
    tenant: u32,
    arrival_us: f64,
    deadline_us: f64,
    row: Vec<f32>,
}

/// Serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// All metrics.
    pub metrics: ServeMetrics,
    /// Policy used.
    pub policy: &'static str,
}

impl ServeReport {
    /// Render for humans.
    pub fn render(&self) -> String {
        format!("policy={}\n{}", self.policy, self.metrics.render())
    }
}

/// The multi-tenant server.
pub struct Server<B: ModelBackend> {
    backend: B,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Admission control.
    pub admission: Admission,
}

impl<B: ModelBackend> Server<B> {
    /// New server.
    pub fn new(backend: B, policy: BatchPolicy) -> Self {
        Server {
            backend,
            policy,
            admission: Admission::default(),
        }
    }

    /// Borrow the backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (warmup etc.).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Replay a trace in virtual time with real service executions.
    /// Request payloads are deterministic hash01 rows.
    pub fn replay(&mut self, trace: &Trace) -> ServeReport {
        let mut metrics = ServeMetrics::default();
        let mut queues: BTreeMap<String, VecDeque<Pending>> = BTreeMap::new();
        let reqs = &trace.requests;
        let mut next = 0usize;
        let mut now = 0.0f64;
        while next < reqs.len() || queues.values().any(|q| !q.is_empty()) {
            // 1. admit arrivals
            while next < reqs.len() && reqs[next].arrival_us <= now + 1e-9 {
                let r = &reqs[next];
                next += 1;
                let d_in = self.backend.d_in(&r.model);
                let q = queues.entry(r.model.clone()).or_default();
                let est = self.backend.estimate_us(&r.model, q.len() as u32 + 1);
                let slack_after = r.deadline_us - now - est;
                match self.admission.decide(q.len(), slack_after) {
                    Admit::Reject => metrics.drop_request(r.tenant),
                    Admit::Accept => q.push_back(Pending {
                        tenant: r.tenant,
                        arrival_us: r.arrival_us,
                        deadline_us: r.deadline_us,
                        row: golden::gen_hash01(d_in, r.id.wrapping_mul(7919)),
                    }),
                }
            }
            // 2. pick the queue whose head deadline is earliest
            let pick = queues
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .min_by(|(_, a), (_, b)| {
                    let da = a.iter().map(|p| p.deadline_us).fold(f64::INFINITY, f64::min);
                    let db = b.iter().map(|p| p.deadline_us).fold(f64::INFINITY, f64::min);
                    da.partial_cmp(&db).unwrap()
                })
                .map(|(m, _)| m.clone());
            let Some(model) = pick else {
                // idle: jump to next arrival
                if next < reqs.len() {
                    now = now.max(reqs[next].arrival_us);
                    continue;
                }
                break;
            };
            // 3. launch or hold
            let launch_at = self.hold_until(&model, &queues[&model], now);
            let next_arrival = reqs.get(next).map(|r| r.arrival_us);
            if now + 1e-9 < launch_at {
                // wait for either the window to close or a new arrival
                now = match next_arrival {
                    Some(t) if t < launch_at => t,
                    _ => launch_at,
                };
                continue;
            }
            // 4. execute: EDF order within the queue, up to max batch
            let q = queues.get_mut(&model).expect("picked");
            let max_b = self.backend.max_batch(&model) as usize;
            let take = match self.policy {
                BatchPolicy::NoBatching => 1,
                BatchPolicy::Coalescing { .. } => q.len().min(max_b),
            };
            let mut batch: Vec<Pending> = q.drain(..take).collect();
            batch.sort_by(|a, b| a.deadline_us.partial_cmp(&b.deadline_us).unwrap());
            let rows: Vec<Vec<f32>> = batch.iter().map(|p| p.row.clone()).collect();
            match self.backend.execute(&model, &rows) {
                Ok(exec) => {
                    now += exec.duration_us;
                    metrics.batch(rows.len() as u32, exec.batch, exec.duration_us);
                    for p in &batch {
                        metrics.complete(p.tenant, now - p.arrival_us, now <= p.deadline_us);
                    }
                }
                Err(e) => {
                    crate::util::logging::emit(
                        crate::util::logging::Level::Error,
                        format_args!("execute {model} failed: {e}"),
                    );
                    for p in &batch {
                        metrics.drop_request(p.tenant);
                    }
                }
            }
        }
        metrics.span_us = now;
        ServeReport {
            metrics,
            policy: self.policy.name(),
        }
    }

    /// When may the given queue launch, per the coalescing policy?
    fn hold_until(&mut self, model: &str, q: &VecDeque<Pending>, _now: f64) -> f64 {
        match self.policy {
            BatchPolicy::NoBatching => 0.0,
            BatchPolicy::Coalescing {
                window_us,
                target_batch,
                safety_margin_us,
            } => {
                let max_b = self.backend.max_batch(model);
                if q.len() as u32 >= target_batch.min(max_b) {
                    return 0.0; // full enough: go now
                }
                let est = self.backend.estimate_us(model, q.len() as u32);
                let critical = q
                    .iter()
                    .map(|p| p.deadline_us)
                    .fold(f64::INFINITY, f64::min)
                    - est
                    - safety_margin_us;
                let oldest = q
                    .iter()
                    .map(|p| p.arrival_us)
                    .fold(f64::INFINITY, f64::min);
                critical.min(oldest + window_us)
            }
        }
    }

    /// Threaded real-time mode: a generator thread paces the trace on the
    /// wall clock (compressed by `speedup`), the current thread batches and
    /// executes. Returns wall-clock metrics.
    pub fn run_realtime(&mut self, trace: &Trace, speedup: f64) -> ServeReport {
        struct Incoming {
            tenant: u32,
            model: String,
            slo_us: f64,
            sent: Instant,
            row: Vec<f32>,
        }
        let (tx, rx) = mpsc::channel::<Incoming>();
        let reqs: Vec<(f64, u32, String, f64, u64)> = trace
            .requests
            .iter()
            .map(|r| {
                (
                    r.arrival_us / speedup,
                    r.tenant,
                    r.model.clone(),
                    r.deadline_us - r.arrival_us,
                    r.id,
                )
            })
            .collect();
        let d_ins: BTreeMap<String, usize> = reqs
            .iter()
            .map(|(_, _, m, _, _)| (m.clone(), self.backend.d_in(m)))
            .collect();
        let gen = std::thread::spawn(move || {
            let t0 = Instant::now();
            for (at_us, tenant, model, slo, id) in reqs {
                let target = Duration::from_micros(at_us as u64);
                let elapsed = t0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                let d_in = d_ins.get(&model).copied().unwrap_or(0);
                let _ = tx.send(Incoming {
                    tenant,
                    model,
                    slo_us: slo,
                    sent: Instant::now(),
                    row: golden::gen_hash01(d_in, id.wrapping_mul(7919)),
                });
            }
        });

        let mut metrics = ServeMetrics::default();
        let mut queues: BTreeMap<String, VecDeque<(Incoming, Instant)>> = BTreeMap::new();
        let t0 = Instant::now();
        let mut disconnected = false;
        loop {
            // drain the channel (bounded wait when idle)
            let timeout = Duration::from_micros(500);
            match rx.recv_timeout(timeout) {
                Ok(inc) => {
                    let now = Instant::now();
                    queues
                        .entry(inc.model.clone())
                        .or_default()
                        .push_back((inc, now));
                    // keep draining whatever already arrived
                    while let Ok(inc) = rx.try_recv() {
                        let now = Instant::now();
                        queues
                            .entry(inc.model.clone())
                            .or_default()
                            .push_back((inc, now));
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
            }
            // launch every queue that is due (window close or full)
            let models: Vec<String> = queues
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .map(|(m, _)| m.clone())
                .collect();
            for model in models {
                let q = queues.get_mut(&model).expect("exists");
                let max_b = self.backend.max_batch(&model) as usize;
                let (window_us, target) = match self.policy {
                    BatchPolicy::NoBatching => (0.0, 1usize),
                    BatchPolicy::Coalescing {
                        window_us,
                        target_batch,
                        ..
                    } => (window_us, target_batch as usize),
                };
                let oldest_wait = q
                    .front()
                    .map(|(_, t)| t.elapsed().as_secs_f64() * 1e6)
                    .unwrap_or(0.0);
                let due = q.len() >= target.min(max_b) || oldest_wait >= window_us;
                if !due {
                    continue;
                }
                let take = match self.policy {
                    BatchPolicy::NoBatching => 1,
                    _ => q.len().min(max_b),
                };
                let batch: Vec<(Incoming, Instant)> = q.drain(..take).collect();
                let rows: Vec<Vec<f32>> = batch.iter().map(|(i, _)| i.row.clone()).collect();
                if let Ok(exec) = self.backend.execute(&model, &rows) {
                    metrics.batch(rows.len() as u32, exec.batch, exec.duration_us);
                    for (inc, _) in &batch {
                        let lat_us = inc.sent.elapsed().as_secs_f64() * 1e6;
                        metrics.complete(inc.tenant, lat_us, lat_us <= inc.slo_us);
                    }
                } else {
                    for (inc, _) in &batch {
                        metrics.drop_request(inc.tenant);
                    }
                }
            }
            if disconnected && queues.values().all(|q| q.is_empty()) {
                break;
            }
        }
        gen.join().expect("generator thread");
        metrics.span_us = t0.elapsed().as_secs_f64() * 1e6;
        ServeReport {
            metrics,
            policy: self.policy.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{ArrivalKind, TenantSpec, Trace};

    /// Deterministic fake backend: fixed per-row cost + fixed overhead,
    /// pad-up to pow2 variants like the real artifact set.
    struct FakeBackend {
        fixed_us: f64,
        per_row_us: f64,
        max_b: u32,
        calls: u64,
    }

    impl FakeBackend {
        fn new() -> Self {
            FakeBackend {
                fixed_us: 500.0,
                per_row_us: 50.0,
                max_b: 16,
                calls: 0,
            }
        }
    }

    impl ModelBackend for FakeBackend {
        fn execute(&mut self, _model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
            self.calls += 1;
            let batch = (rows.len() as u32).next_power_of_two().min(self.max_b);
            let dur = self.fixed_us + self.per_row_us * batch as f64;
            Ok(ModelExec {
                outputs: rows.iter().map(|_| vec![0.0; 4]).collect(),
                batch,
                duration_us: dur,
            })
        }

        fn estimate_us(&mut self, _m: &str, n: u32) -> f64 {
            self.fixed_us + self.per_row_us * n.max(1) as f64
        }

        fn max_batch(&self, _m: &str) -> u32 {
            self.max_b
        }

        fn d_in(&self, _m: &str) -> usize {
            4
        }
    }

    fn tenants(n: u32, rate: f64, slo_us: u64) -> Vec<TenantSpec> {
        (0..n)
            .map(|i| TenantSpec::new(i, "m", slo_us, rate, ArrivalKind::Poisson))
            .collect()
    }

    #[test]
    fn coalescing_batches_more_than_fifo() {
        let trace = Trace::generate(&tenants(8, 200.0, 100_000), 50, 42);
        let mut fifo = Server::new(FakeBackend::new(), BatchPolicy::NoBatching);
        let r1 = fifo.replay(&trace);
        let mut coal = Server::new(FakeBackend::new(), BatchPolicy::coalescing());
        let r2 = coal.replay(&trace);
        assert!(r2.metrics.mean_occupancy() > 2.0 * r1.metrics.mean_occupancy());
        assert!(r2.metrics.batches < r1.metrics.batches);
        // all requests accounted for in both
        assert_eq!(r1.metrics.total_completed(), 400);
        assert_eq!(r2.metrics.total_completed(), 400);
    }

    #[test]
    fn coalescing_improves_slo_under_load() {
        // 8 tenants at high rate: FIFO's serialization blows deadlines,
        // coalescing amortizes the fixed cost
        let trace = Trace::generate(&tenants(8, 400.0, 30_000), 80, 7);
        let mut fifo = Server::new(FakeBackend::new(), BatchPolicy::NoBatching);
        let a1 = fifo.replay(&trace).metrics.overall_attainment();
        let mut coal = Server::new(FakeBackend::new(), BatchPolicy::coalescing());
        let a2 = coal.replay(&trace).metrics.overall_attainment();
        assert!(a2 > a1, "coalescing {a2} must beat fifo {a1}");
        assert!(a2 > 0.9, "coalescing attainment {a2}");
    }

    #[test]
    fn light_load_latency_stays_low() {
        let trace = Trace::generate(&tenants(2, 20.0, 100_000), 30, 3);
        let mut s = Server::new(FakeBackend::new(), BatchPolicy::coalescing());
        let r = s.replay(&trace);
        assert_eq!(r.metrics.overall_attainment(), 1.0);
        // nobody waits longer than window + exec
        for t in r.metrics.tenants.values() {
            assert!(t.latency.max_us() < 3_000.0 + 500.0 + 50.0 * 16.0 + 1_000.0);
        }
    }

    #[test]
    fn tight_slo_forces_early_launch() {
        // single tenant, huge window, but SLO 2ms: the safety margin must
        // launch well before the 50ms window
        let trace = Trace::generate(&tenants(1, 100.0, 2_000), 20, 9);
        let mut s = Server::new(
            FakeBackend::new(),
            BatchPolicy::Coalescing {
                window_us: 50_000.0,
                target_batch: 16,
                safety_margin_us: 200.0,
            },
        );
        let r = s.replay(&trace);
        assert!(
            r.metrics.overall_attainment() > 0.8,
            "attainment {}",
            r.metrics.overall_attainment()
        );
    }

    #[test]
    fn overload_drops_via_admission() {
        let trace = Trace::generate(&tenants(4, 5_000.0, 1_000), 400, 5);
        let mut s = Server::new(FakeBackend::new(), BatchPolicy::coalescing());
        s.admission = Admission::new(32);
        let r = s.replay(&trace);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert!(drops > 0, "overload must shed load");
        // completed + dropped == offered
        assert_eq!(r.metrics.total_completed() + drops, 1600);
    }

    #[test]
    fn realtime_mode_serves_everything() {
        let trace = Trace::generate(&tenants(3, 300.0, 200_000), 10, 11);
        let mut s = Server::new(FakeBackend::new(), BatchPolicy::coalescing());
        let r = s.run_realtime(&trace, 50.0); // 50x compressed
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 30);
        assert!(r.metrics.span_us > 0.0);
    }
}
