//! The serving surface: policies, backends, and thin drive-mode
//! constructors over the ONE serving event loop in
//! [`crate::serve::engine`].
//!
//! There is exactly ONE scheduler in this repo (`compiler::{window,
//! scheduler, jit}`) and, since the Clock × LaunchStage refactor, exactly
//! ONE serving loop driving it ([`crate::serve::engine::Engine`]). This
//! module maps requests onto the JIT's declarative dispatch IR:
//!
//! * each **(tenant, model)** pair is a stream of execution in the
//!   paper's sense;
//! * each **model** is a coalescing *group*: requests for one model pack
//!   into one launch (up to the model's largest compiled batch variant),
//!   requests for different models never share a launch;
//! * each **request** carries its SLO and its input row as the attached
//!   payload — marked *independent* of its stream's earlier requests
//!   (stateless inference) so a hot tenant's burst rides one superkernel
//!   launch (see [`Server::independent_streams`]);
//! * a pack launch executes as one padded model batch through
//!   [`ModelBackend::execute`] (the [`ServeExecutor`] adapter).
//!
//! Every public drive mode is a thin constructor choosing a cell of the
//! engine's mode matrix (see the [`crate::serve::engine`] module docs for
//! the full table, the threading model of the wall-clock runs, and why
//! virtual time keeps the synchronous admission gate):
//!
//! * [`Server::replay`] — virtual × single-worker timeline;
//! * [`Server::replay_placed`] — virtual × fleet timelines (+ optional
//!   rebalance);
//! * [`Server::run_realtime`] — wall × inline (± frontend);
//! * [`Server::run_realtime_pooled`] — wall × pool over an anonymous
//!   homogeneous fleet (± frontend);
//! * [`Server::run_realtime_placed`] — wall × pool over a device
//!   topology (+ optional rebalance, ± frontend).
//!
//! Admission and the scheduler share one estimator
//! ([`ServeExecutor::estimate_group_us`]), resolved through the tiered
//! Measured/Tuned/Prior cost model in [`crate::estimate`] and priced at
//! the *padded* compiled variant that will actually run — they can no
//! longer disagree. A [`crate::estimate::TunedCache`] loaded into
//! [`Server::tuned`] warm-starts pricing before any observation lands.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{mpsc, Arc};

use crate::analysis::audit::AuditLog;
use crate::compiler::coalescer::{Coalescer, SuperKernel};
use crate::compiler::ir::TensorOp;
use crate::compiler::jit::{JitCompiler, JitConfig, PackExecutor, PackMember, PackRun};
use crate::compiler::scheduler::Policy;
use crate::estimate::{
    shape_class_label, Estimator, EstimatorStats, TieredEstimator, TunedCache,
    TunedEntry, VariantKey,
};
use crate::gpu::device::DeviceSpec;
use crate::gpu::kernel::KernelDesc;
use crate::placement::{
    relative_speed, DeviceTopology, PlacementTable, RebalanceConfig, Rebalancer,
};
use crate::runtime::executor::{ModelExec, PjrtExecutor};
use crate::serve::admission::Admission;
use crate::serve::engine::{
    seed_placement, trace_arrivals, Arrival, Engine, EngineConfig, Incoming,
    InlineStage, OpEvent, Placement, PoolStage, ServeJit, TimelineStage,
    VirtualClock, WallClock,
};
use crate::serve::metrics::ServeMetrics;
use crate::util::threadpool::StatefulPool;
use crate::workload::trace::{TenantSpec, Trace};
use crate::Result;

/// Batching policy.
#[derive(Debug, Clone)]
pub enum BatchPolicy {
    /// Batch-1 FIFO (the early-binding baseline).
    NoBatching,
    /// SLO-aware coalescing (the paper's approach).
    Coalescing {
        /// Max hold time for the oldest queued request, µs.
        window_us: f64,
        /// Launch as soon as this many requests are queued.
        target_batch: u32,
        /// Slack reserve before a deadline forces a launch, µs.
        safety_margin_us: f64,
    },
}

impl BatchPolicy {
    /// Default coalescing parameters.
    pub fn coalescing() -> Self {
        BatchPolicy::Coalescing {
            window_us: 3_000.0,
            target_batch: 8,
            safety_margin_us: 1_000.0,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::NoBatching => "batch1-fifo",
            BatchPolicy::Coalescing { .. } => "ooo-coalescing",
        }
    }

    /// Lower the serving policy onto the JIT core's knobs: per-model pack
    /// caps (largest compiled variant) and the shared scheduler policy.
    pub(crate) fn jit_config(&self, models: &[ModelSlot], window_capacity: usize) -> JitConfig {
        let max_b = models
            .iter()
            .map(|m| m.max_batch as usize)
            .max()
            .unwrap_or(1)
            .max(1);
        let (policy, max_problems) = match *self {
            BatchPolicy::NoBatching => (
                Policy {
                    coalesce_window_us: 0.0,
                    target_pack: 1,
                    safety_margin_us: 0.0,
                    ..Policy::default()
                },
                1,
            ),
            BatchPolicy::Coalescing {
                window_us,
                target_batch,
                safety_margin_us,
            } => (
                Policy {
                    coalesce_window_us: window_us,
                    target_pack: (target_batch as usize).max(1),
                    safety_margin_us,
                    ..Policy::default()
                },
                max_b,
            ),
        };
        let mut coalescer = Coalescer::new(max_problems, 1.0);
        for (g, m) in models.iter().enumerate() {
            coalescer
                .group_caps
                .insert(g as u64, (m.max_batch as usize).max(1));
        }
        JitConfig {
            policy,
            coalescer,
            window_capacity,
            packing_overhead_us: 0.0,
        }
    }
}

/// Backend abstraction (real PJRT or a test stub).
pub trait ModelBackend {
    /// Execute a batch of rows on a model.
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec>;
    /// Estimated service time for a batch of `n`, µs. Implementations
    /// should price the padded variant that `n` rows would actually run.
    fn estimate_us(&self, model: &str, n: u32) -> f64;
    /// Largest compiled batch.
    fn max_batch(&self, model: &str) -> u32;
    /// Input feature count.
    fn d_in(&self, model: &str) -> usize;
    /// The batch size `n` rows actually execute at (smallest compiled
    /// variant that fits). Defaults to no padding knowledge.
    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        n.max(1).min(self.max_batch(model).max(1))
    }
}

impl<B: ModelBackend + ?Sized> ModelBackend for &mut B {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        (**self).execute(model, rows)
    }

    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        (**self).estimate_us(model, n)
    }

    fn max_batch(&self, model: &str) -> u32 {
        (**self).max_batch(model)
    }

    fn d_in(&self, model: &str) -> usize {
        (**self).d_in(model)
    }

    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        (**self).padded_batch(model, n)
    }
}

impl ModelBackend for PjrtExecutor {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        PjrtExecutor::execute_model(self, model, rows)
    }

    /// Service-time estimate for `n` rows: the *padded compiled variant*
    /// that will actually run, using the learned per-artifact latency when
    /// available, else the FLOPS-proportional prior scaled by the padded
    /// batch (not the raw `n` — underestimating the padded launch made the
    /// old batcher hold too long near deadlines).
    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        let Ok(entry) = self.manifest().model(model) else {
            return 1_000.0;
        };
        let per_query = entry.flops_per_query as f64;
        match entry.variant_for(n.max(1)) {
            Some(art) => self.estimate_file(&art.file, per_query * art.batch as f64),
            // batch exceeds the largest variant: extrapolate on the prior
            None => per_query * n.max(1) as f64 / (self.prior_gflops * 1e3),
        }
    }

    fn max_batch(&self, model: &str) -> u32 {
        self.manifest()
            .model(model)
            .map(|e| e.max_batch())
            .unwrap_or(1)
    }

    fn d_in(&self, model: &str) -> usize {
        self.manifest()
            .model(model)
            .map(|e| e.d_in as usize)
            .unwrap_or(0)
    }

    fn padded_batch(&self, model: &str, n: u32) -> u32 {
        self.manifest()
            .model(model)
            .ok()
            .and_then(|e| e.variant_for(n.max(1)).map(|a| a.batch))
            .unwrap_or_else(|| self.max_batch(model))
    }
}

/// One served model: the coalescing-group table entry.
#[derive(Debug, Clone)]
pub struct ModelSlot {
    /// Manifest model name.
    pub name: String,
    /// Input feature count.
    pub d_in: usize,
    /// Largest compiled batch variant.
    pub max_batch: u32,
}

/// Adapter: executes JIT packs as padded model batches on a
/// [`ModelBackend`]. This is what makes `JitCompiler` the single serving
/// core — estimation (admission + scheduler) and execution both live here.
pub struct ServeExecutor<B: ModelBackend> {
    backend: B,
    models: Vec<ModelSlot>,
    /// the ONE cost model: per-(device class, group, padded batch)
    /// variants resolved Measured → Tuned → Prior (see
    /// [`crate::estimate`]); keyed per class so a t4 observation never
    /// updates a v100 estimate
    est: TieredEstimator,
    /// relative speed per device class (index = class id); a single 1.0
    /// entry for the legacy single-device drive modes
    class_speeds: Vec<f64>,
    /// device-class names (index = class id) — the Tuned cache's device
    /// key; defaults to the v100 reference class for unplaced modes
    class_names: Vec<String>,
    /// primary device class per group (the estimation target for
    /// admission and the scheduler); groups default to class 0
    group_class: HashMap<u64, u32>,
}

impl<B: ModelBackend> ServeExecutor<B> {
    /// New adapter over a backend and the run's model table.
    pub fn new(backend: B, models: Vec<ModelSlot>) -> Self {
        ServeExecutor {
            backend,
            models,
            est: TieredEstimator::new(Policy::default().ewma_alpha),
            class_speeds: vec![1.0],
            class_names: vec!["v100".to_string()],
            group_class: HashMap::new(),
        }
    }

    /// Borrow the backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// The model table (group id = index).
    pub fn models(&self) -> &[ModelSlot] {
        &self.models
    }

    /// Install the fleet's device-class speed table (relative throughput,
    /// index = class id). The placed drive modes call this once at startup.
    pub fn set_class_speeds(&mut self, speeds: Vec<f64>) {
        if !speeds.is_empty() {
            self.class_speeds = speeds;
        }
    }

    /// Install the fleet's device-class names (index = class id) — the
    /// key the Tuned tier's cache entries match against.
    pub fn set_class_names(&mut self, names: Vec<String>) {
        if !names.is_empty() {
            self.class_names = names;
        }
    }

    /// Measured-tier EWMA smoothing factor (`Policy::ewma_alpha`);
    /// applied to variants observed from now on, so the engine sets it
    /// once at startup before any launch completes.
    pub fn set_ewma_alpha(&mut self, alpha: f64) {
        self.est.set_alpha(alpha);
    }

    /// Configure the Tuned-tier refinement cadence from policy
    /// (`Policy::{refine_period, refine_top, refine_err_threshold_us}`):
    /// the estimator quarters the period while its error p99 exceeds the
    /// threshold and backs off once the Measured tier dominates.
    pub fn set_refine(&mut self, period: u64, top: usize, err_threshold_us: f64) {
        self.est.set_refine(period, top);
        self.est.set_refine_err_threshold_us(err_threshold_us);
    }

    /// Warm-start the Tuned tier from a loaded artifact cache: every
    /// (model, device class, padded variant) this run could price gets
    /// its cached estimate, so admission and the scheduler see realistic
    /// costs before the first launch completes.
    ///
    /// When a variant has no entry for its own device class, a matching
    /// entry tuned on *another* class seeds it instead, scaled by the two
    /// classes' relative speeds (see [`cross_device_estimate`]) — a fleet
    /// that already tuned its v100s prices a freshly added t4 from the
    /// v100 numbers rather than falling all the way back to the analytic
    /// prior.
    pub fn warm_start(&mut self, cache: &TunedCache) {
        for (gi, slot) in self.models.iter().enumerate() {
            let mut padded_set: BTreeSet<u32> = BTreeSet::new();
            for n in 1..=slot.max_batch.max(1) {
                padded_set.insert(self.backend.padded_batch(&slot.name, n));
            }
            for (class, cname) in self.class_names.iter().enumerate() {
                for &padded in &padded_set {
                    let est_us = cache
                        .get(&slot.name, cname, padded)
                        .or_else(|| cross_device_estimate(cache, &slot.name, cname, padded));
                    if let Some(est_us) = est_us {
                        self.est.warm(
                            VariantKey {
                                class: class as u32,
                                group: gi as u64,
                                padded,
                            },
                            est_us,
                        );
                    }
                }
            }
        }
    }

    /// Export everything the learned tiers know as a persistable
    /// [`TunedCache`] — measured values shadow warm-started ones, so a
    /// save-at-exit hands the next cold start this run's refined
    /// estimates. Deterministic (sorted variant order).
    pub fn export_tuned(&self) -> TunedCache {
        let mut cache = TunedCache::new();
        for (key, est_us, _tier) in self.est.export() {
            let Some(slot) = self.models.get(key.group as usize) else {
                continue;
            };
            let Some(device) = self.class_names.get(key.class as usize) else {
                continue;
            };
            let class = shape_class_label(&KernelDesc::gemm(
                key.padded,
                slot.d_in.max(1) as u32,
                1,
            ));
            cache.insert(&slot.name, device, key.padded, TunedEntry { class, est_us });
        }
        cache
    }

    /// Snapshot of the estimator's per-tier hit counters and
    /// prediction-error histogram.
    pub fn estimator_stats(&self) -> EstimatorStats {
        self.est.stats()
    }

    /// Tier-change generation: moves when a variant's answer changes for
    /// a non-EWMA reason (first measurement overtaking a warm-started
    /// value, or a warm start landing). Consumers that memoize estimate
    /// tables — the published `AdmissionView` — re-derive when it moves.
    pub fn estimator_generation(&self) -> u64 {
        self.est.generation()
    }

    /// Pin a group's primary estimation class (follows the placement
    /// table's primary replica; updated again after every rebalance).
    pub fn set_group_class(&mut self, group: u64, class: u32) {
        self.group_class.insert(group, class);
    }

    /// The device class a group's estimates are currently priced on.
    pub fn class_of_group(&self, group: u64) -> u32 {
        self.group_class.get(&group).copied().unwrap_or(0)
    }

    fn speed_of_class(&self, class: u32) -> f64 {
        self.class_speeds
            .get(class as usize)
            .copied()
            .unwrap_or(1.0)
            .max(1e-9)
    }

    /// Estimated service time of `n` queued requests for a model group,
    /// priced at the padded compiled variant that would actually run on
    /// the group's *primary device class* — the ONE estimator shared by
    /// admission and the scheduler.
    pub fn estimate_group_us(&self, group: u64, n: u32) -> f64 {
        self.estimate_group_on_class_us(group, self.class_of_group(group), n)
    }

    /// Estimate for an explicit device class, resolved through the tiers:
    /// the class's Measured EWMA when observed, else the warm-started
    /// Tuned value, else the backend Prior scaled by the class's relative
    /// speed (a t4 runs the same padded variant ~2× longer than the v100
    /// reference). The prior is a lazy closure — the backend's analytic
    /// model only runs when both learned tiers miss.
    pub fn estimate_group_on_class_us(&self, group: u64, class: u32, n: u32) -> f64 {
        let slot = &self.models[group as usize];
        let padded = self.backend.padded_batch(&slot.name, n);
        let key = VariantKey {
            class,
            group,
            padded,
        };
        self.est.estimate_us(key, &|| {
            self.backend.estimate_us(&slot.name, n) / self.speed_of_class(class)
        })
    }

    /// Estimates for launches of 1..=cap ops of a group — the admission
    /// snapshot's table — memoized per padded compiled variant: pow2-ish
    /// padding collapses the table to ~log(cap) distinct estimator
    /// evaluations instead of cap. Entry k equals
    /// `estimate_group_us(group, k + 1)` exactly (`cap` never exceeds the
    /// group's largest compiled variant, so the padded batch determines
    /// the estimate).
    pub fn estimate_group_table_us(&self, group: u64, cap: u32) -> Vec<f64> {
        let slot = &self.models[group as usize];
        let class = self.class_of_group(group);
        let mut cache: HashMap<u32, f64> = HashMap::new();
        (1..=cap.max(1))
            .map(|n| {
                let padded = self.backend.padded_batch(&slot.name, n);
                *cache
                    .entry(padded)
                    .or_insert_with(|| self.estimate_group_on_class_us(group, class, n))
            })
            .collect()
    }

    fn observe_group(&mut self, class: u32, group: u64, padded: u32, us: f64) {
        // the prior is computed eagerly here (it scores prediction error
        // in the estimator even when a learned tier already answers)
        let prior_us = {
            let slot = &self.models[group as usize];
            self.backend.estimate_us(&slot.name, padded) / self.speed_of_class(class)
        };
        self.est.observe(
            VariantKey {
                class,
                group,
                padded,
            },
            us,
            prior_us,
        );
    }
}

impl<B: ModelBackend> PackExecutor<Vec<f32>> for ServeExecutor<B> {
    fn estimate_pack_us(&self, _k: &KernelDesc, ops: &[&TensorOp]) -> f64 {
        match ops.first() {
            Some(op) => self.estimate_group_us(op.group, ops.len() as u32),
            None => 0.0,
        }
    }

    fn estimate_generation(&self) -> u64 {
        self.estimator_generation()
    }

    fn execute_pack(
        &mut self,
        sk: &SuperKernel,
        members: &[PackMember<'_, Vec<f32>>],
    ) -> PackRun {
        let group = members.first().map(|m| m.op.group).unwrap_or(0);
        let name = self.models[group as usize].name.clone();
        let rows: Vec<Vec<f32>> = members.iter().map(|m| m.payload.clone()).collect();
        match self.backend.execute(&name, &rows) {
            Ok(exec) => PackRun {
                duration_us: exec.duration_us,
                executed: exec.batch,
                ok: true,
                device_class: 0,
            },
            Err(e) => {
                crate::util::logging::emit(
                    crate::util::logging::Level::Error,
                    format_args!("execute {name} failed: {e}"),
                );
                PackRun {
                    duration_us: 0.0,
                    executed: sk.kernel.problems,
                    ok: false,
                    device_class: 0,
                }
            }
        }
    }

    fn observe_pack(&mut self, _sk: &SuperKernel, ops: &[&TensorOp], run: &PackRun) {
        if !run.ok {
            return;
        }
        if let Some(op) = ops.first() {
            self.observe_group(run.device_class, op.group, run.executed, run.duration_us);
        }
    }
}

/// Deterministic simulator backend: fixed per-launch overhead plus a
/// per-row cost, padding up to power-of-two compiled variants like the
/// real artifact set. Drives `vliwd bench` and the CI smoke run (no PJRT
/// artifacts required) and the serving unit tests.
#[derive(Debug, Clone)]
pub struct SimBackend {
    /// Fixed per-launch overhead, µs.
    pub fixed_us: f64,
    /// Marginal cost per padded row, µs.
    pub per_row_us: f64,
    /// Largest compiled batch variant.
    pub max_b: u32,
    /// Input feature count (every model).
    pub d_in: usize,
}

impl Default for SimBackend {
    fn default() -> Self {
        SimBackend {
            fixed_us: 500.0,
            per_row_us: 50.0,
            max_b: 16,
            d_in: 4,
        }
    }
}

impl ModelBackend for SimBackend {
    fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
        let batch = self.padded_batch(model, rows.len() as u32);
        let dur = self.fixed_us + self.per_row_us * batch as f64;
        Ok(ModelExec {
            outputs: rows.iter().map(|_| vec![0.0; 4]).collect(),
            batch,
            duration_us: dur,
        })
    }

    fn estimate_us(&self, model: &str, n: u32) -> f64 {
        let padded = self.padded_batch(model, n);
        self.fixed_us + self.per_row_us * padded as f64
    }

    fn max_batch(&self, _m: &str) -> u32 {
        self.max_b
    }

    fn d_in(&self, _m: &str) -> usize {
        self.d_in
    }

    fn padded_batch(&self, _m: &str, n: u32) -> u32 {
        n.max(1).next_power_of_two().min(self.max_b)
    }
}

/// Serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// All metrics.
    pub metrics: ServeMetrics,
    /// Policy used.
    pub policy: &'static str,
    /// Everything the estimator learned this run, exported as a
    /// persistable artifact cache (measured values shadowing warm-started
    /// ones) — save it to warm-start the next run.
    pub tuned: TunedCache,
}

impl ServeReport {
    /// Render for humans.
    pub fn render(&self) -> String {
        format!("policy={}\n{}", self.policy, self.metrics.render())
    }
}

/// Cross-device transfer for the Tuned tier: when `target` has no cached
/// entry for (model, padded batch), borrow the first entry tuned for the
/// same variant on a *different* device class (deterministic: the cache
/// iterates in sorted key order) and rescale it by the two classes'
/// relative throughput — duration scales inversely with speed, so a
/// v100 entry seeds a t4 estimate at `est × speed(v100) / speed(t4)`.
/// Unknown device names (either side) transfer nothing; the variant then
/// falls back to the analytic prior as before.
fn cross_device_estimate(
    cache: &TunedCache,
    model: &str,
    target: &str,
    padded: u32,
) -> Option<f64> {
    let target_speed = DeviceSpec::by_name(target).map(|s| relative_speed(&s))?;
    cache.iter().find_map(|((m, device, batch), e)| {
        if m != model || *batch != padded || device == target {
            return None;
        }
        let source_speed = DeviceSpec::by_name(device).map(|s| relative_speed(&s))?;
        Some(e.est_us * source_speed / target_speed)
    })
}

/// Build the run's model table (group id = sorted-name index) from the
/// trace and the backend's manifest knowledge.
fn model_slots<B: ModelBackend>(
    backend: &B,
    trace: &Trace,
) -> (Vec<ModelSlot>, BTreeMap<String, u64>) {
    let mut names: BTreeSet<String> =
        trace.tenants.iter().map(|t| t.model.clone()).collect();
    for r in &trace.requests {
        names.insert(r.model.clone());
    }
    let slots: Vec<ModelSlot> = names
        .iter()
        .map(|n| ModelSlot {
            name: n.clone(),
            d_in: backend.d_in(n),
            max_batch: backend.max_batch(n).max(1),
        })
        .collect();
    let index: BTreeMap<String, u64> = slots
        .iter()
        .enumerate()
        .map(|(i, s)| (s.name.clone(), i as u64))
        .collect();
    (slots, index)
}

/// The common per-run wiring every drive-mode constructor needs: built
/// ONCE by [`Server::engine_parts`] so the five thin constructors cannot
/// drift in how they derive the model table, seed placement, lower the
/// trace, or configure the JIT.
struct EngineParts<'a, B: ModelBackend> {
    slots: Vec<ModelSlot>,
    arrivals: Vec<Arrival>,
    /// LPT-seeded placement table over the given topology (None when the
    /// mode runs unplaced).
    table: Option<PlacementTable>,
    jit: ServeJit<&'a mut B>,
    config: EngineConfig,
}

/// The multi-tenant server.
pub struct Server<B: ModelBackend> {
    backend: B,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Admission control.
    pub admission: Admission,
    /// JIT issue-window capacity — the backpressure backstop behind
    /// admission.
    pub window_capacity: usize,
    /// Treat requests within one (tenant, model) stream as independent
    /// (stateless inference, the default): a tenant's burst may then
    /// coalesce into one launch and issue out of arrival order within its
    /// stream. Turn off for deployments whose per-stream requests carry
    /// state — program order then binds and at most one request per stream
    /// rides each launch.
    pub independent_streams: bool,
    /// Run admission on a dedicated frontend stage thread (the default)
    /// in the wall-clock drive modes, so tenant accept/reject decisions
    /// never wait on an engine iteration — see [`crate::serve::frontend`].
    /// With the flag off the gate runs synchronously between channel
    /// drains (kept for comparison benches). The virtual-time `replay*`
    /// modes always use the synchronous gate: a wall-clock frontend would
    /// race the virtual clock and break replay determinism.
    pub frontend: bool,
    /// Warm-start cache for the estimator's Tuned tier (loaded from
    /// `artifacts/tuned.json` by the CLI): every drive mode prices
    /// matching (model, device class, padded batch) variants from it
    /// until a real observation lands. `None` = cold start.
    pub tuned: Option<TunedCache>,
    /// Per-tenant token-bucket rate limits: tenant → (rate req/s, burst).
    /// Shaped requests are rejected *before* pricing in both gates, so a
    /// tenant saturating its bucket never moves the admission price other
    /// tenants see. Tenants absent from the map are unshaped.
    pub tenant_rates: BTreeMap<u32, (f64, f64)>,
    /// Launch-log auditor ([`crate::analysis::audit`]): when set, every
    /// drive mode streams admission/launch/completion/rebalance/reply
    /// events to it as JSONL for offline `vliwd audit` replay
    /// (`serve`/`bench --launch-log`). `None` = no event logging.
    pub launch_log: Option<Arc<AuditLog>>,
    /// Override for the issue-time machine verifier
    /// ([`Policy::verify_plans`](crate::compiler::scheduler::Policy::verify_plans)):
    /// `Some(v)` forces it on/off; `None` keeps the build default
    /// (on under `debug_assertions`, off in release).
    pub verify_plans: Option<bool>,
}

impl<B: ModelBackend> Server<B> {
    /// New server.
    pub fn new(backend: B, policy: BatchPolicy) -> Self {
        Server {
            backend,
            policy,
            admission: Admission::default(),
            window_capacity: 1024,
            independent_streams: true,
            frontend: true,
            tuned: None,
            tenant_rates: BTreeMap::new(),
            launch_log: None,
            verify_plans: None,
        }
    }

    /// Borrow the backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (warmup etc.).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Build the per-run wiring shared by EVERY drive-mode constructor:
    /// the model/group table, the trace lowered to engine arrivals, the
    /// LPT-seeded placement table (when a topology applies), the
    /// configured JIT over this server's backend, and the engine options.
    /// One implementation so the five thin constructors cannot drift.
    fn engine_parts(
        &mut self,
        trace: &Trace,
        topo: Option<&DeviceTopology>,
        use_frontend: bool,
    ) -> EngineParts<'_, B> {
        let (slots, index) = model_slots(&self.backend, trace);
        let arrivals = trace_arrivals(trace, &index);
        let mut cfg = self.policy.jit_config(&slots, self.window_capacity);
        if let Some(v) = self.verify_plans {
            cfg.policy.verify_plans = v;
        }
        let config = EngineConfig {
            admission: self.admission.clone(),
            independent_streams: self.independent_streams,
            frontend: use_frontend,
            policy: self.policy.name(),
            tenant_rates: self.tenant_rates.clone(),
        };
        // The executor IS the run's one cost model: configure its Measured
        // tier from policy, teach it the fleet's device-class names, and
        // warm-start the Tuned tier from the loaded artifact cache BEFORE
        // anything (placement seeding included) asks it for a price.
        let mut exec = ServeExecutor::new(&mut self.backend, slots.clone());
        exec.set_ewma_alpha(cfg.policy.ewma_alpha);
        exec.set_refine(
            cfg.policy.refine_period,
            cfg.policy.refine_top,
            cfg.policy.refine_err_threshold_us,
        );
        if let Some(t) = topo {
            exec.set_class_names(
                t.classes().iter().map(|c| c.name.clone()).collect(),
            );
        }
        if let Some(cache) = &self.tuned {
            exec.warm_start(cache);
        }
        let table = topo.map(|t| {
            seed_placement(&exec, trace, &index, slots.len() as u64, t)
        });
        let jit = JitCompiler::with_payloads(cfg, exec);
        EngineParts {
            slots,
            arrivals,
            table,
            jit,
            config,
        }
    }

    /// Replay a trace in virtual time with real service executions,
    /// entirely through the unified engine: the **virtual × single-worker
    /// timeline** cell of the mode matrix, i.e. exactly
    /// [`Server::replay_placed`] on a one-v100 topology minus the
    /// per-device metrics (pinned by
    /// `prop_replay_and_replay_placed_agree_on_single_v100`).
    /// Deterministic given a trace and a deterministic backend. Request
    /// payloads are deterministic hash01 rows.
    pub fn replay(&mut self, trace: &Trace) -> ServeReport {
        let topo = DeviceTopology::homogeneous(1, DeviceSpec::v100());
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(trace, Some(&topo), false);
        let table = parts.table.expect("seeded table");
        let engine = Engine::new(
            parts.jit,
            VirtualClock::new(),
            TimelineStage::new(1),
            Some(Placement {
                topo,
                table,
                rebal: None,
                report_devices: false,
            }),
            parts.slots,
            parts.config,
        )
        .with_audit(audit);
        engine.run_virtual(&parts.arrivals).0
    }

    /// Multi-device virtual-time replay: launches route through a
    /// placement table onto per-worker device timelines (heterogeneous
    /// speeds, per-class learned estimates), with optional hot-group
    /// rebalancing — the **virtual × fleet-timeline** cells of the mode
    /// matrix. Deterministic given a trace, a deterministic backend, and
    /// a fixed topology. Returns the report plus the final placement
    /// table.
    pub fn replay_placed(
        &mut self,
        trace: &Trace,
        topo: &DeviceTopology,
        rebalance: Option<RebalanceConfig>,
    ) -> (ServeReport, PlacementTable) {
        let rebal = rebalance.map(|c| Rebalancer::new(c, topo.len()));
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(trace, Some(topo), false);
        let table = parts.table.expect("seeded table");
        let engine = Engine::new(
            parts.jit,
            VirtualClock::new(),
            TimelineStage::new(topo.len()),
            Some(Placement {
                topo: topo.clone(),
                table,
                rebal,
                report_devices: true,
            }),
            parts.slots,
            parts.config,
        )
        .with_audit(audit);
        let (report, table) = engine.run_virtual(&parts.arrivals);
        (report, table.expect("placed run returns its table"))
    }

    /// Threaded real-time mode: a generator thread paces the trace on the
    /// wall clock (compressed by `speedup`); the engine drives the JIT
    /// and executes launches inline — the **wall × inline** cell, with
    /// admission on the frontend stage per [`Server::frontend`]. Returns
    /// wall-clock metrics.
    pub fn run_realtime(&mut self, trace: &Trace, speedup: f64) -> ServeReport
    where
        B: 'static,
    {
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(trace, None, self.frontend);
        Engine::new(
            parts.jit,
            WallClock::new(),
            InlineStage::new(),
            None,
            parts.slots,
            parts.config,
        )
        .with_audit(audit)
        .run_wall(parts.arrivals, speedup)
    }

    /// Wire-driven real-time mode: the engine's intake channel is fed by
    /// the network intake shards ([`crate::serve::intake`]) instead of a
    /// trace generator, and terminal per-op outcomes flow back out on
    /// `reply` for the intake reply router — the **wall × inline** cell
    /// with an external request source. `tenants` declares the served
    /// models (they size the model/group table); no requests are
    /// synthesized. Runs until every sender of `rx` is dropped and the
    /// window drains.
    pub(crate) fn run_wire(
        &mut self,
        tenants: &[TenantSpec],
        rx: mpsc::Receiver<Incoming>,
        reply: mpsc::Sender<OpEvent>,
    ) -> ServeReport
    where
        B: 'static,
    {
        let trace = Trace {
            requests: vec![],
            tenants: tenants.to_vec(),
        };
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(&trace, None, self.frontend);
        Engine::new(
            parts.jit,
            WallClock::new(),
            InlineStage::new(),
            None,
            parts.slots,
            parts.config,
        )
        .with_audit(audit)
        .with_reply_sink(reply)
        .run_wall_rx(rx)
    }

    /// Concurrent real-time mode: launches fan out to `workers` pool
    /// workers, each owning its own backend built by `factory` on its own
    /// thread (the backend type need not be `Send`) — the **wall × pool**
    /// cell. The stage routes through a placement table over an anonymous
    /// homogeneous fleet (one device class), so superkernels for
    /// different models execute in parallel while one model's launches
    /// stay serialized (and cache-warm) on their placed worker. Device
    /// names are NOT reported — this mode runs on whatever hardware the
    /// caller's backends really use, and `metrics.devices` staying empty
    /// is the documented single-device-modes contract.
    pub fn run_realtime_pooled<F>(
        &mut self,
        trace: &Trace,
        speedup: f64,
        workers: usize,
        factory: F,
    ) -> ServeReport
    where
        B: 'static,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let pool = StatefulPool::new(workers, factory);
        let topo = DeviceTopology::homogeneous(workers, DeviceSpec::v100());
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(trace, Some(&topo), self.frontend);
        let table = parts.table.expect("seeded table");
        Engine::new(
            parts.jit,
            WallClock::new(),
            PoolStage::new(&pool),
            Some(Placement {
                topo,
                table,
                rebal: None,
                report_devices: false,
            }),
            parts.slots,
            parts.config,
        )
        .with_audit(audit)
        .run_wall(parts.arrivals, speedup)
    }

    /// Device-placed real-time mode: one pool worker per topology device,
    /// each owning the backend `factory(worker, spec)` builds on its own
    /// thread — the **wall × pool × placed** cells. Launches route to the
    /// least-loaded replica of their group's placement-table entry; when
    /// `rebalance` is set, hot groups replicate onto cooler devices (and
    /// cold ones migrate off hot devices) as per-device load skews.
    pub fn run_realtime_placed<F>(
        &mut self,
        trace: &Trace,
        speedup: f64,
        topo: DeviceTopology,
        rebalance: Option<RebalanceConfig>,
        factory: F,
    ) -> ServeReport
    where
        B: 'static,
        F: Fn(usize, &DeviceSpec) -> B + Send + Sync + 'static,
    {
        let specs = topo.clone();
        let pool = StatefulPool::new(topo.len(), move |i| factory(i, specs.spec_of(i)));
        let rebal = rebalance.map(|c| Rebalancer::new(c, topo.len()));
        let audit = self.launch_log.clone();
        let parts = self.engine_parts(trace, Some(&topo), self.frontend);
        let table = parts.table.expect("seeded table");
        Engine::new(
            parts.jit,
            WallClock::new(),
            PoolStage::new(&pool),
            Some(Placement {
                topo,
                table,
                rebal,
                report_devices: true,
            }),
            parts.slots,
            parts.config,
        )
        .with_audit(audit)
        .run_wall(parts.arrivals, speedup)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::compiler::ir::SloClass;
    use crate::workload::trace::{ArrivalKind, Request, TenantSpec, Trace};

    /// The deterministic simulator backend (public as [`SimBackend`]):
    /// fixed per-launch overhead + per-row cost, pow2 padded variants.
    fn sim() -> SimBackend {
        SimBackend::default()
    }

    fn tenants(n: u32, rate: f64, slo_us: u64) -> Vec<TenantSpec> {
        (0..n)
            .map(|i| TenantSpec::new(i, "m", slo_us, rate, ArrivalKind::Poisson))
            .collect()
    }

    #[test]
    fn coalescing_batches_more_than_fifo() {
        let trace = Trace::generate(&tenants(8, 200.0, 100_000), 50, 42);
        let mut fifo = Server::new(sim(), BatchPolicy::NoBatching);
        let r1 = fifo.replay(&trace);
        let mut coal = Server::new(sim(), BatchPolicy::coalescing());
        let r2 = coal.replay(&trace);
        assert!(r2.metrics.mean_occupancy() > 2.0 * r1.metrics.mean_occupancy());
        assert!(r2.metrics.batches < r1.metrics.batches);
        // all requests accounted for in both
        assert_eq!(r1.metrics.total_completed(), 400);
        assert_eq!(r2.metrics.total_completed(), 400);
    }

    #[test]
    fn coalescing_improves_slo_under_load() {
        // 8 tenants at high rate: FIFO's serialization blows deadlines,
        // coalescing amortizes the fixed cost
        let trace = Trace::generate(&tenants(8, 400.0, 30_000), 80, 7);
        let mut fifo = Server::new(sim(), BatchPolicy::NoBatching);
        let a1 = fifo.replay(&trace).metrics.overall_attainment();
        let mut coal = Server::new(sim(), BatchPolicy::coalescing());
        let a2 = coal.replay(&trace).metrics.overall_attainment();
        assert!(a2 > a1, "coalescing {a2} must beat fifo {a1}");
        assert!(a2 > 0.9, "coalescing attainment {a2}");
    }

    #[test]
    fn light_load_latency_stays_low() {
        let trace = Trace::generate(&tenants(2, 20.0, 100_000), 30, 3);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.replay(&trace);
        assert_eq!(r.metrics.overall_attainment(), 1.0);
        // nobody waits longer than window + exec
        for t in r.metrics.tenants.values() {
            assert!(t.latency.max_us() < 3_000.0 + 500.0 + 50.0 * 16.0 + 1_000.0);
        }
    }

    #[test]
    fn tight_slo_forces_early_launch() {
        // single tenant, huge window, but SLO 2ms: the safety margin must
        // launch well before the 50ms window
        let trace = Trace::generate(&tenants(1, 100.0, 2_000), 20, 9);
        let mut s = Server::new(
            sim(),
            BatchPolicy::Coalescing {
                window_us: 50_000.0,
                target_batch: 16,
                safety_margin_us: 200.0,
            },
        );
        let r = s.replay(&trace);
        assert!(
            r.metrics.overall_attainment() > 0.8,
            "attainment {}",
            r.metrics.overall_attainment()
        );
    }

    #[test]
    fn overload_drops_via_admission() {
        let trace = Trace::generate(&tenants(4, 5_000.0, 1_000), 400, 5);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        s.admission = Admission::new(32);
        let r = s.replay(&trace);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert!(drops > 0, "overload must shed load");
        // completed + dropped == offered
        assert_eq!(r.metrics.total_completed() + drops, 1600);
    }

    #[test]
    fn no_batching_runs_batch_one() {
        let trace = Trace::generate(&tenants(4, 100.0, 100_000), 20, 21);
        let mut s = Server::new(sim(), BatchPolicy::NoBatching);
        let r = s.replay(&trace);
        assert_eq!(r.metrics.total_completed(), 80);
        assert_eq!(r.metrics.mean_occupancy(), 1.0);
        assert_eq!(r.metrics.jit.mean_pack(), 1.0);
    }

    #[test]
    fn replay_is_deterministic_through_unified_core() {
        // two identical traces through the unified engine must produce
        // identical metrics (deterministic backend => deterministic
        // schedule, bit-for-bit)
        let trace = Trace::generate(&tenants(4, 150.0, 50_000), 40, 13);
        let run = || {
            let mut s = Server::new(sim(), BatchPolicy::coalescing());
            s.replay(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        assert_eq!(a.metrics.batches, b.metrics.batches);
        assert_eq!(a.metrics.useful_rows, b.metrics.useful_rows);
        assert_eq!(a.metrics.padded_rows, b.metrics.padded_rows);
        assert_eq!(a.metrics.span_us.to_bits(), b.metrics.span_us.to_bits());
        assert_eq!(a.metrics.busy_us.to_bits(), b.metrics.busy_us.to_bits());
        assert_eq!(a.metrics.jit.launches, b.metrics.jit.launches);
        assert_eq!(a.metrics.jit.slo_hits, b.metrics.jit.slo_hits);
        for (ta, tb) in a.metrics.tenants.iter().zip(b.metrics.tenants.iter()) {
            assert_eq!(ta.0, tb.0);
            assert_eq!(ta.1.slo_hits, tb.1.slo_hits);
            assert_eq!(ta.1.slo_misses, tb.1.slo_misses);
            assert_eq!(ta.1.dropped, tb.1.dropped);
            assert_eq!(
                ta.1.latency.quantile_us(0.99).to_bits(),
                tb.1.latency.quantile_us(0.99).to_bits()
            );
        }
    }

    #[test]
    fn jit_pack_stats_surface_in_metrics() {
        let trace = Trace::generate(&tenants(6, 300.0, 100_000), 30, 17);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.replay(&trace);
        assert!(r.metrics.jit.launches > 0);
        assert!(r.metrics.jit.mean_pack() > 1.0, "packing must happen");
        let eff = r.metrics.jit.pack_efficiency();
        assert!(eff > 0.0 && eff <= 1.0 + 1e-9, "eff={eff}");
        assert!(r.render().contains("jit:"), "report shows jit stats");
    }

    fn burst_trace(n: usize, gap_us: f64, slo_us: u64) -> Trace {
        let requests = (0..n)
            .map(|i| Request {
                id: i as u64,
                tenant: 0,
                model: "m".to_string(),
                arrival_us: i as f64 * gap_us,
                deadline_us: i as f64 * gap_us + slo_us as f64,
                class: SloClass::Standard,
            })
            .collect();
        Trace {
            requests,
            tenants: vec![TenantSpec::new(0, "m", slo_us, 1_000.0, ArrivalKind::Poisson)],
        }
    }

    #[test]
    fn single_tenant_burst_coalesces_at_no_attainment_cost() {
        // 8 requests from ONE (tenant, model) stream, 50µs apart. Under
        // the independence contract the burst rides multi-problem packs;
        // with program order binding the same burst serializes into
        // singleton launches and loses SLOs.
        let trace = burst_trace(8, 50.0, 3_000);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r_ind = s.replay(&trace);
        let mut s_dep = Server::new(sim(), BatchPolicy::coalescing());
        s_dep.independent_streams = false;
        let r_dep = s_dep.replay(&trace);
        assert!(
            r_ind.metrics.jit.mean_pack() > 1.5,
            "burst must coalesce, mean_pack {}",
            r_ind.metrics.jit.mean_pack()
        );
        assert_eq!(
            r_dep.metrics.jit.mean_pack(),
            1.0,
            "dependent stream keeps one op per launch"
        );
        assert!(
            r_ind.metrics.overall_attainment() >= r_dep.metrics.overall_attainment(),
            "coalescing may never lose attainment: {} vs {}",
            r_ind.metrics.overall_attainment(),
            r_dep.metrics.overall_attainment()
        );
        assert_eq!(r_ind.metrics.total_completed(), 8);
        assert!(r_ind.metrics.same_stream_rows > 0, "burst shares launches");
        assert_eq!(r_dep.metrics.same_stream_rows, 0);
        // conservation in the dependent run too (late burst members may be
        // shed by the per-op drain pricing — they were doomed anyway)
        let dep_drops: u64 = r_dep.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r_dep.metrics.total_completed() + dep_drops, 8);
    }

    #[test]
    fn per_device_class_ewmas_are_isolated() {
        // the worker-aware-estimates contract: a t4 (class 1) observation
        // must never update the v100 (class 0) estimate, and vice versa
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let mut ex = ServeExecutor::new(&mut backend, slots);
        ex.set_class_speeds(vec![1.0, 0.5]);
        let prior_v100 = ex.estimate_group_on_class_us(0, 0, 4);
        let prior_t4 = ex.estimate_group_on_class_us(0, 1, 4);
        // unlearned estimates fall back to the backend prior scaled by the
        // class's relative speed: the t4 prior is 2x the v100 prior
        assert!((prior_t4 - prior_v100 * 2.0).abs() < 1e-9);
        // a t4 observation lands in the t4 slot only
        ex.observe_group(1, 0, 4, 9_999.0);
        assert_eq!(
            ex.estimate_group_on_class_us(0, 0, 4),
            prior_v100,
            "t4 observation must not touch the v100 estimate"
        );
        assert_eq!(ex.estimate_group_on_class_us(0, 1, 4), 9_999.0);
        // and a v100 observation leaves the learned t4 estimate alone
        ex.observe_group(0, 0, 4, 123.0);
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 4), 123.0);
        assert_eq!(ex.estimate_group_on_class_us(0, 1, 4), 9_999.0);
        // the group's primary class picks which estimate admission sees
        assert_eq!(ex.estimate_group_us(0, 4), 123.0, "default class 0");
        ex.set_group_class(0, 1);
        assert_eq!(ex.estimate_group_us(0, 4), 9_999.0);
    }

    #[test]
    fn warm_start_prices_before_first_observation() {
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let prior = backend.estimate_us("m", 4);
        let mut ex = ServeExecutor::new(&mut backend, slots);
        let mut cache = TunedCache::new();
        cache.insert(
            "m",
            "v100",
            4,
            TunedEntry {
                class: "4x4x4".to_string(),
                est_us: 777.0,
            },
        );
        ex.warm_start(&cache);
        // warmed variant answers from the Tuned tier before any launch
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 4), 777.0);
        // un-warmed variants still fall back to the analytic prior
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 8), prior + 50.0 * 4.0);
        let stats = ex.estimator_stats();
        assert_eq!(stats.tuned_hits, 1);
        assert_eq!(stats.prior_hits, 1);
        // the first real observation overtakes the warm entry...
        let gen = ex.estimator_generation();
        ex.observe_group(0, 0, 4, 500.0);
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 4), 500.0);
        // ...and bumps the generation so published views refresh
        assert!(ex.estimator_generation() > gen, "tier change must be visible");
    }

    #[test]
    fn export_tuned_round_trips_through_warm_start() {
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut b1 = sim();
        let mut learned = ServeExecutor::new(&mut b1, slots.clone());
        learned.observe_group(0, 0, 4, 640.0);
        learned.observe_group(0, 0, 8, 980.0);
        let cache = learned.export_tuned();
        assert_eq!(cache.len(), 2);
        // a fresh executor warm-started from the export prices identically
        let mut b2 = sim();
        let mut warmed = ServeExecutor::new(&mut b2, slots);
        warmed.warm_start(&cache);
        assert_eq!(
            warmed.estimate_group_on_class_us(0, 0, 4).to_bits(),
            learned.estimate_group_on_class_us(0, 0, 4).to_bits()
        );
        assert_eq!(
            warmed.estimate_group_on_class_us(0, 0, 8).to_bits(),
            learned.estimate_group_on_class_us(0, 0, 8).to_bits()
        );
    }

    #[test]
    fn warm_start_seeds_absent_device_class_from_cross_device_entry() {
        // a t4-only fleet warm-starting from a cache tuned entirely on
        // v100s: the v100 entry transfers, rescaled by relative speed,
        // instead of the variant falling back to the analytic prior
        let slots = vec![ModelSlot {
            name: "m".to_string(),
            d_in: 4,
            max_batch: 16,
        }];
        let mut backend = sim();
        let mut ex = ServeExecutor::new(&mut backend, slots.clone());
        ex.set_class_names(vec!["t4".to_string()]);
        let mut cache = TunedCache::new();
        cache.insert(
            "m",
            "v100",
            4,
            TunedEntry {
                class: "4x4x4".to_string(),
                est_us: 800.0,
            },
        );
        ex.warm_start(&cache);
        let v100 = relative_speed(&DeviceSpec::v100());
        let t4 = relative_speed(&DeviceSpec::by_name("t4").unwrap());
        let want = 800.0 * v100 / t4;
        assert!(want > 800.0, "duration scales inversely with speed");
        assert_eq!(ex.estimate_group_on_class_us(0, 0, 4), want);
        assert_eq!(ex.estimator_stats().tuned_hits, 1, "Tuned tier answered");
        // a same-device entry always wins over any cross-device transfer
        let mut exact = cache.clone();
        exact.insert(
            "m",
            "t4",
            4,
            TunedEntry {
                class: "4x4x4".to_string(),
                est_us: 1234.0,
            },
        );
        let mut b2 = sim();
        let mut ex2 = ServeExecutor::new(&mut b2, slots);
        ex2.set_class_names(vec!["t4".to_string()]);
        ex2.warm_start(&exact);
        assert_eq!(ex2.estimate_group_on_class_us(0, 0, 4), 1234.0);
        // unknown device names on either side transfer nothing
        assert!(cross_device_estimate(&cache, "m", "not-a-device", 4).is_none());
    }

    #[test]
    fn tenant_rate_limit_sheds_and_is_invisible_to_other_tenants() {
        // tenant 0 offers ~400 req/s against a 50 req/s bucket; tenant 1
        // is unshaped and must ride through untouched
        let trace = Trace::generate(&tenants(2, 400.0, 100_000), 100, 77);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        s.tenant_rates.insert(0, (50.0, 1.0));
        let r = s.replay(&trace);
        assert!(
            r.metrics.classes[SloClass::Standard.index()].shaped > 0,
            "the bucket must shed"
        );
        assert!(r.metrics.tenants[&0].dropped > 0, "shaped tenant drops");
        assert_eq!(r.metrics.tenants[&1].dropped, 0, "unshaped tenant rides");
        // conservation: completed + dropped == offered
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 200);
    }

    #[test]
    fn warm_started_replay_attainment_is_no_worse() {
        // the BENCH_6 contract in miniature: replay cold, save what was
        // learned, replay the same trace warm-started — attainment must
        // not regress and the Tuned tier must actually answer
        let trace = Trace::generate(&tenants(4, 400.0, 8_000), 60, 23);
        let mut cold_s = Server::new(sim(), BatchPolicy::coalescing());
        let cold = cold_s.replay(&trace);
        let mut warm_s = Server::new(sim(), BatchPolicy::coalescing());
        warm_s.tuned = Some(cold.tuned.clone());
        let warm = warm_s.replay(&trace);
        assert!(
            warm.metrics.overall_attainment() >= cold.metrics.overall_attainment(),
            "warm {} < cold {}",
            warm.metrics.overall_attainment(),
            cold.metrics.overall_attainment()
        );
        assert!(
            warm.metrics.estimator.tuned_hits > 0,
            "warm run must answer from the Tuned tier"
        );
        assert_eq!(cold.metrics.estimator.tuned_hits, 0, "cold run has no cache");
    }

    /// A fleet-saturating two-model workload: `hot` overloads one v100,
    /// `cold` idles along — the rebalancer's bread and butter.
    fn skewed_trace(per_tenant: usize) -> Trace {
        let tenants = vec![
            TenantSpec::new(0, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(3, "cold", 30_000, 300.0, ArrivalKind::Poisson),
        ];
        Trace::generate(&tenants, per_tenant, 71)
    }

    fn heavy_sim() -> SimBackend {
        // per-row cost high enough that 6000 hot rows/s overload a single
        // v100-speed worker (batch-8 launch = 1800µs -> ~4400 rows/s)
        SimBackend {
            fixed_us: 200.0,
            per_row_us: 200.0,
            max_b: 8,
            d_in: 4,
        }
    }

    #[test]
    fn replay_placed_replicates_hot_group_and_beats_static_placement() {
        let trace = skewed_trace(400);
        let offered = trace.requests.len() as u64;
        let topo = DeviceTopology::from_names(&["v100".into(), "t4".into()]).unwrap();
        let rb_cfg = RebalanceConfig {
            window_us: 25_000.0,
            ..RebalanceConfig::default()
        };
        // dynamic: rebalancer enabled
        let mut dynamic = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (dyn_report, table) = dynamic.replay_placed(&trace, &topo, Some(rb_cfg));
        // static: the same initial placement, pinned for the whole run
        let mut pinned = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (static_report, _) = pinned.replay_placed(&trace, &topo, None);

        // groups are sorted by model name: cold = 0, hot = 1
        assert!(
            dyn_report.metrics.replications >= 1,
            "the hot group must replicate: {:?}",
            dyn_report.metrics
        );
        assert!(
            table.replicas_of(1).len() >= 2,
            "hot group on both devices: {:?}",
            table.replicas_of(1)
        );
        // both devices pull hot load after replication
        assert_eq!(dyn_report.metrics.devices.len(), 2);
        assert!(dyn_report.metrics.devices[0].busy_us > 0.0);
        assert!(dyn_report.metrics.devices[1].busy_us > 0.0);
        // conservation in both runs
        for r in [&dyn_report, &static_report] {
            let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
            assert_eq!(r.metrics.total_completed() + drops, offered);
        }
        // the acceptance bar: replication buys aggregate throughput at no
        // worse SLO attainment than the pinned placement
        assert!(
            dyn_report.metrics.throughput() > static_report.metrics.throughput(),
            "dynamic {:.0}/s must beat static {:.0}/s",
            dyn_report.metrics.throughput(),
            static_report.metrics.throughput()
        );
        assert!(
            dyn_report.metrics.overall_attainment()
                >= static_report.metrics.overall_attainment(),
            "attainment may not regress: {:.3} vs {:.3}",
            dyn_report.metrics.overall_attainment(),
            static_report.metrics.overall_attainment()
        );
    }

    #[test]
    fn slow_replica_launches_are_not_false_evictions() {
        // v100 + k80: the speed ratio (~4x) exceeds the 3x eviction
        // factor, so once the hot group replicates onto the k80 its
        // k80-routed launches run ~4x the primary-class estimate. The
        // launch estimate is re-priced on the routed class at issue — a
        // slow replica running at its own speed is not a straggler.
        let tenants = vec![
            TenantSpec::new(0, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "hot", 30_000, 2_000.0, ArrivalKind::Poisson),
            TenantSpec::new(3, "cold", 30_000, 150.0, ArrivalKind::Poisson),
        ];
        let trace = Trace::generate(&tenants, 300, 29);
        let topo = DeviceTopology::from_names(&["v100".into(), "k80".into()]).unwrap();
        let mut s = Server::new(heavy_sim(), BatchPolicy::coalescing());
        let (r, table) = s.replay_placed(
            &trace,
            &topo,
            Some(RebalanceConfig {
                window_us: 25_000.0,
                ..RebalanceConfig::default()
            }),
        );
        assert!(
            r.metrics.replications >= 1,
            "hot group must replicate onto the k80"
        );
        assert!(table.replicas_of(1).len() >= 2);
        assert_eq!(
            r.metrics.jit.evictions, 0,
            "slow-replica launches must not count as stragglers"
        );
    }

    #[test]
    fn replay_placed_single_worker_conserves_and_reports_devices() {
        let trace = Trace::generate(&tenants(4, 150.0, 100_000), 30, 19);
        let topo = DeviceTopology::from_names(&["v100".into()]).unwrap();
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let (r, table) = s.replay_placed(&trace, &topo, None);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 120);
        assert_eq!(r.metrics.devices.len(), 1);
        assert_eq!(r.metrics.devices[0].name, "v100");
        assert!(r.metrics.devices[0].launches > 0);
        assert!(table.is_total(1, 1), "single group on the single worker");
        assert!(r.render().contains("device 0 (v100)"));
    }

    #[test]
    fn replay_placed_is_deterministic() {
        let trace = skewed_trace(120);
        let topo = DeviceTopology::from_names(&["v100".into(), "t4".into()]).unwrap();
        let run = || {
            let mut s = Server::new(heavy_sim(), BatchPolicy::coalescing());
            let (r, _) = s.replay_placed(
                &trace,
                &topo,
                Some(RebalanceConfig {
                    window_us: 25_000.0,
                    ..RebalanceConfig::default()
                }),
            );
            r
        };
        let a = run();
        let b = run();
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        assert_eq!(a.metrics.batches, b.metrics.batches);
        assert_eq!(a.metrics.span_us.to_bits(), b.metrics.span_us.to_bits());
        assert_eq!(a.metrics.replications, b.metrics.replications);
        assert_eq!(a.metrics.migrations, b.metrics.migrations);
        for (da, db) in a.metrics.devices.iter().zip(b.metrics.devices.iter()) {
            assert_eq!(da.launches, db.launches);
            assert_eq!(da.busy_us.to_bits(), db.busy_us.to_bits());
        }
    }

    /// Backend that wedges the calling thread for a fixed stall per
    /// execute — simulates the engine thread being stuck mid-iteration
    /// (inline launch mode executes on the engine thread).
    struct StallingBackend {
        inner: SimBackend,
        stall: Duration,
    }

    impl ModelBackend for StallingBackend {
        fn execute(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<ModelExec> {
            std::thread::sleep(self.stall);
            self.inner.execute(model, rows)
        }

        fn estimate_us(&self, model: &str, n: u32) -> f64 {
            self.inner.estimate_us(model, n)
        }

        fn max_batch(&self, model: &str) -> u32 {
            self.inner.max_batch(model)
        }

        fn d_in(&self, model: &str) -> usize {
            self.inner.d_in(model)
        }

        fn padded_batch(&self, model: &str, n: u32) -> u32 {
            self.inner.padded_batch(model, n)
        }
    }

    #[test]
    fn frontend_admission_latency_bounded_under_scheduler_stall() {
        // with the engine thread stalled 10ms mid-iteration (every inline
        // execute sleeps), frontend admission p99 stays under 1ms —
        // decisions ride the published snapshot, never the engine thread.
        // 120 samples so the p99 tolerates a single OS-scheduling outlier
        // on loaded CI machines.
        let trace = burst_trace(120, 300.0, 1_000_000); // 1s SLO: none doomed
        let mut s = Server::new(
            StallingBackend {
                inner: sim(),
                stall: Duration::from_millis(10),
            },
            BatchPolicy::coalescing(),
        );
        let r = s.run_realtime(&trace, 1.0);
        assert_eq!(
            r.metrics.admission_decisions, 120,
            "every request gets a frontend decision"
        );
        let p99 = r.metrics.admission_latency.quantile_us(0.99);
        assert!(
            p99 < 1_000.0,
            "frontend admission p99 {p99}µs must not wait on the scheduler"
        );
        assert!(
            r.metrics.stale_decisions > 0,
            "stalled iterations must surface as stale-view decisions"
        );
        // conservation through the frontend path
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 120);

        // contrast: the synchronous gate decides between channel drains,
        // so its admission latency eats the stalls
        let mut s2 = Server::new(
            StallingBackend {
                inner: sim(),
                stall: Duration::from_millis(10),
            },
            BatchPolicy::coalescing(),
        );
        s2.frontend = false;
        let r2 = s2.run_realtime(&trace, 1.0);
        let sync_p99 = r2.metrics.admission_latency.quantile_us(0.99);
        assert!(
            sync_p99 > p99,
            "sync gate p99 {sync_p99}µs must show the stall the frontend {p99}µs hides"
        );
    }

    #[test]
    fn realtime_mode_serves_everything() {
        let trace = Trace::generate(&tenants(3, 300.0, 200_000), 10, 11);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.run_realtime(&trace, 50.0); // 50x compressed
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 30);
        assert!(r.metrics.span_us > 0.0);
        assert!(r.metrics.jit.launches > 0, "served through the JIT core");
        // the frontend stage (default-on) decided every request
        assert_eq!(r.metrics.admission_decisions, 30);
        assert!(r.metrics.frontend_wait.count() > 0, "channel wait recorded");
    }

    #[test]
    fn realtime_sync_gate_still_serves() {
        // the pre-frontend path stays available (and measured): decisions
        // happen at drain time, so latency == channel wait
        let trace = Trace::generate(&tenants(2, 200.0, 200_000), 8, 31);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        s.frontend = false;
        let r = s.run_realtime(&trace, 50.0);
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 16);
        assert_eq!(r.metrics.admission_decisions, 16);
        assert_eq!(
            r.metrics.admission_latency.count(),
            r.metrics.frontend_wait.count(),
            "sync gate records decision latency and channel wait together"
        );
        assert_eq!(r.metrics.stale_decisions, 0, "no snapshots on the sync path");
    }

    #[test]
    fn realtime_pooled_serves_two_models_concurrently() {
        // two models → two coalescing groups → two pool workers, each
        // owning its own backend; every request completes or is shed
        let tenants = vec![
            TenantSpec::new(0, "alpha", 200_000, 300.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "beta", 200_000, 300.0, ArrivalKind::Poisson),
            TenantSpec::new(2, "alpha", 200_000, 300.0, ArrivalKind::Poisson),
        ];
        let trace = Trace::generate(&tenants, 10, 23);
        let mut s = Server::new(sim(), BatchPolicy::coalescing());
        let r = s.run_realtime_pooled(&trace, 50.0, 2, |_| sim());
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 30);
        assert!(r.metrics.jit.launches > 0);
        assert!(r.metrics.batches > 0);
    }

    #[test]
    fn realtime_placed_with_frontend_spans_the_mode_cell() {
        // wall × placed-pool × frontend: before the unified engine this
        // combination had no test (the frontend was only exercised
        // inline, the placed stage only with the sync gate) — now it is
        // one constructor call over the same loop as everything else
        let tenants = vec![
            TenantSpec::new(0, "alpha", 200_000, 300.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "beta", 200_000, 300.0, ArrivalKind::Poisson),
        ];
        let trace = Trace::generate(&tenants, 10, 37);
        let topo = DeviceTopology::from_names(&["v100".into(), "t4".into()]).unwrap();
        let mut s = Server::new(sim(), BatchPolicy::coalescing()); // frontend default on
        let r = s.run_realtime_placed(
            &trace,
            50.0,
            topo,
            Some(RebalanceConfig::default()),
            |_, _| sim(),
        );
        let drops: u64 = r.metrics.tenants.values().map(|t| t.dropped).sum();
        assert_eq!(r.metrics.total_completed() + drops, 20, "conservation");
        assert_eq!(
            r.metrics.admission_decisions, 20,
            "the frontend decided every request"
        );
        assert_eq!(r.metrics.devices.len(), 2, "placed run reports both devices");
        assert!(r.metrics.jit.launches > 0);
    }
}
